"""Engine-layer tests: the registry, the unified driver, and the strategies.

The acceptance sweep runs EVERY registered exact engine (including the
Pallas backend in interpret mode) against ``naive_topk`` on random,
sparse, and negative-weight queries — new engines registered later are
covered automatically.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    EngineContext,
    batch_bucket,
    blocked_topk,
    chunked_ta_topk,
    engine_names,
    get_engine,
    list_engines,
    merge_topk_sorted,
    naive_topk,
    norm_pruned_topk,
    pruned_block_scan,
    select_engine,
    ta_round_strategy,
    threshold_topk_np,
)
from repro.core.index import build_index
from repro.core.strategies import blocked_lists_strategy, norm_block_strategy


def _queries(rng, b, r):
    """Random, sparse (mostly-zero), and mixed-sign/negative queries."""
    dense = rng.standard_normal((b, r)).astype(np.float32)
    sparse = dense.copy()
    sparse[rng.random((b, r)) < 0.7] = 0.0
    sparse[np.all(sparse == 0, axis=1), 0] = 1.0
    mixed = dense.copy()
    mixed[:, ::2] *= -1.0
    negative = -np.abs(dense)
    return {"random": dense, "sparse": sparse, "mixed_sign": mixed,
            "negative": negative}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


def test_registry_contents_and_metadata():
    names = engine_names()
    for expected in ("naive", "ta", "bta", "norm", "norm_sharded",
                     "pallas", "fagin", "partial", "auto"):
        assert expected in names
    assert not get_engine("naive").needs_index
    assert get_engine("pallas").backend == "pallas"
    # layout declarations (DESIGN.md §7)
    assert get_engine("ta").layout == "list_major"
    assert get_engine("bta").layout == "list_major"
    assert get_engine("norm").layout == "norm_major"
    assert get_engine("norm_sharded").layout == "norm_sharded"
    # host-only reference oracles: exact, numpy backend, never jitted
    for oracle in ("fagin", "partial"):
        e = get_engine(oracle)
        assert e.exact and e.host_only and e.backend == "numpy"
        assert e.make_batched is None and e.dispatch is not None
    # aliases resolve to canonical engines
    assert get_engine("threshold").name == "ta"
    assert get_engine("blocked").name == "bta"
    assert get_engine("norm_pruned").name == "norm"
    assert get_engine("topk_mips").name == "pallas"


def test_registry_unknown_engine_raises():
    with pytest.raises(ValueError, match="unknown engine"):
        get_engine("definitely_not_an_engine")


def test_list_engines_filters():
    assert all(e.exact for e in list_engines(exact=True))
    pallas = list_engines(backend="pallas")
    assert [e.name for e in pallas] == ["pallas"]
    assert all(not e.needs_index for e in list_engines(needs_index=False))


# ---------------------------------------------------------------------------
# Acceptance sweep: every exact engine vs naive on all query regimes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,r,k", [(37, 8, 5), (256, 16, 1), (300, 12, 10)])
def test_every_exact_engine_matches_naive(m, r, k):
    rng = np.random.default_rng(m * r + k)
    T = rng.standard_normal((m, r)).astype(np.float32)
    ctx = EngineContext(T, block_size=16)
    for regime, U in _queries(rng, 4, r).items():
        Uj = jnp.asarray(U)
        ref = np.sort(np.asarray(naive_topk(ctx.targets, Uj, k).values),
                      axis=1)
        for eng in list_engines(exact=True):
            res = eng.run(ctx, Uj, k)
            np.testing.assert_allclose(
                np.sort(np.asarray(res.values), axis=1), ref, atol=1e-3,
                err_msg=f"engine={eng.name} regime={regime}")


def test_engine_ids_are_valid_catalogue_ids():
    rng = np.random.default_rng(11)
    T = rng.standard_normal((123, 9)).astype(np.float32)
    ctx = EngineContext(T, block_size=16)
    U = jnp.asarray(rng.standard_normal((3, 9)).astype(np.float32))
    for eng in list_engines(exact=True):
        res = eng.run(ctx, U, 5)
        ids = np.asarray(res.indices)
        vals = np.asarray(res.values)
        scores = np.asarray(U) @ T.T
        for b in range(ids.shape[0]):
            np.testing.assert_allclose(scores[b, ids[b]], vals[b], atol=1e-3,
                                       err_msg=eng.name)


# ---------------------------------------------------------------------------
# auto policy
# ---------------------------------------------------------------------------


def test_auto_selects_ta_for_sparse_batches():
    rng = np.random.default_rng(0)
    ctx = EngineContext(rng.standard_normal((500, 24)).astype(np.float32))
    U = np.zeros((4, 24), np.float32)
    U[:, :3] = 1.0
    assert select_engine(ctx, jnp.asarray(U)).name == "ta"


def test_auto_selects_norm_backend_for_decaying_catalogues():
    rng = np.random.default_rng(1)
    T = rng.standard_normal((2000, 16)).astype(np.float32)
    T *= (1.0 / np.sqrt(1.0 + np.arange(2000)))[:, None]
    ctx = EngineContext(T)
    U = jnp.asarray(rng.standard_normal((4, 16)).astype(np.float32))
    assert select_engine(ctx, U).name in ("norm", "pallas")


def test_auto_selects_bta_for_dense_flat_catalogues():
    # B-aware policy (DESIGN.md §11): BTA needs BOTH a flat spectrum and
    # a batch big enough to amortise the batched-native list scan
    rng = np.random.default_rng(2)
    ctx = EngineContext(rng.standard_normal((1000, 16)).astype(np.float32),
                        prefix_depth=64)
    U = jnp.asarray(rng.standard_normal((8, 16)).astype(np.float32))
    assert select_engine(ctx, U).name == "bta"
    # below the amortisation threshold the shared-tile norm scan wins
    assert select_engine(ctx, U[:2]).name in ("norm", "pallas")
    # with the list layout off there is no batched path at any B: the
    # per-query list loop never beats the contiguous norm scan
    ctx_off = EngineContext(
        rng.standard_normal((1000, 16)).astype(np.float32), prefix_depth=0)
    assert select_engine(ctx_off, U).name in ("norm", "pallas")


def test_auto_sparse_small_batch_avoids_lockstep_list_scan():
    # sparse queries still pick TA when the batched path is live (B >= 8)
    # or when the layout is off (cache-resident gather path); a SMALL
    # batch with the layout on would pay the per-query lockstep loop, so
    # the policy falls through to the norm scan
    rng = np.random.default_rng(3)
    U = np.zeros((8, 24), np.float32)
    U[:, :3] = 1.0
    ctx = EngineContext(rng.standard_normal((500, 24)).astype(np.float32),
                        prefix_depth=64)
    assert select_engine(ctx, jnp.asarray(U)).name == "ta"
    assert select_engine(ctx, jnp.asarray(U[:2])).name in ("norm", "pallas")


# ---------------------------------------------------------------------------
# Blocked path: mixed-sign and mostly-zero queries vs the numpy oracle
# (the gather-side list flip previously had no direct coverage)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("block", [1, 7, 32])
@pytest.mark.parametrize("regime", ["mixed_sign", "sparse", "negative"])
def test_blocked_flip_and_sparse_match_oracle(block, regime):
    rng = np.random.default_rng(17)
    T = rng.standard_normal((150, 10)).astype(np.float32)
    idx = build_index(T)
    for u in _queries(rng, 3, 10)[regime]:
        ov, _, ostats = threshold_topk_np(T, np.asarray(idx.order_desc), u, 4)
        r = blocked_topk(jnp.asarray(T), idx.order_desc, idx.t_sorted_desc,
                         jnp.asarray(u), 4, block_size=block)
        np.testing.assert_allclose(np.sort(np.asarray(r.values)),
                                   np.sort(ov), atol=1e-4)
        if block == 1:
            # block_size=1 IS the paper's TA round structure, count-for-count
            assert int(r.n_scored) == ostats.n_scored
            assert int(r.depth) == ostats.depth


def test_driver_direct_strategies_agree():
    """The three strategies, run straight through pruned_block_scan."""
    rng = np.random.default_rng(23)
    T = rng.standard_normal((90, 7)).astype(np.float32)
    u = rng.standard_normal(7).astype(np.float32)
    u[2] = 0.0
    u[3] *= -1.0
    idx = build_index(T)
    Tj, uj = jnp.asarray(T), jnp.asarray(u)
    ref = np.sort(np.asarray(naive_topk(Tj, uj, 5).values))
    order, t_sorted, _ = idx.query_views(uj)   # desc arrays + flags
    for strat in (
        ta_round_strategy(order, t_sorted, uj),
        blocked_lists_strategy(idx.order_desc, idx.t_sorted_desc, uj, 8),
        norm_block_strategy(idx.norm_order, idx.norms_sorted, uj, 8),
    ):
        res = pruned_block_scan(Tj, uj, strat, 5)
        np.testing.assert_allclose(np.sort(np.asarray(res.values)), ref,
                                   atol=1e-4)


def test_driver_uniform_halting():
    """max_steps caps every strategy through the same driver argument."""
    rng = np.random.default_rng(29)
    T = rng.standard_normal((400, 12)).astype(np.float32)
    u = rng.standard_normal(12).astype(np.float32)
    idx = build_index(T)
    Tj, uj = jnp.asarray(T), jnp.asarray(u)
    order, t_sorted, _ = idx.query_views(uj)
    for strat in (
        ta_round_strategy(order, t_sorted, uj),
        blocked_lists_strategy(idx.order_desc, idx.t_sorted_desc, uj, 16),
        norm_block_strategy(idx.norm_order, idx.norms_sorted, uj, 16),
    ):
        res = pruned_block_scan(Tj, uj, strat, 5, max_steps=3)
        assert int(res.depth) <= 3


def _tied_problem(rng, m=200, r=8, b=5):
    """Integer-valued catalogue/queries: exact score ties, exact float32
    arithmetic — the adversarial regime for count-faithful stopping."""
    T = rng.integers(-3, 4, (m, r)).astype(np.float32)
    U = rng.integers(-2, 3, (b, r)).astype(np.float32)
    U[np.all(U == 0, axis=1), 0] = 1.0
    return T, U


# ---------------------------------------------------------------------------
# Chunked TA: exactness + n_scored/depth equality vs the sequential oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("chunk", [1, 4, 16, 64])
@pytest.mark.parametrize("regime", ["mixed_sign", "sparse", "random"])
def test_chunked_ta_counts_match_sequential_oracle(chunk, regime):
    rng = np.random.default_rng(41)
    T = rng.standard_normal((180, 12)).astype(np.float32)
    idx = build_index(T)
    for u in _queries(rng, 4, 12)[regime]:
        ov, _, ostats = threshold_topk_np(T, np.asarray(idx.order_desc), u, 6)
        r = chunked_ta_topk(jnp.asarray(T), idx.order_desc,
                            idx.t_sorted_desc, idx.rank_desc,
                            jnp.asarray(u), 6, chunk=chunk)
        np.testing.assert_allclose(np.sort(np.asarray(r.values)),
                                   np.sort(ov), atol=1e-4)
        assert int(r.n_scored) == ostats.n_scored, (chunk, regime)
        assert int(r.depth) == ostats.depth, (chunk, regime)


@pytest.mark.parametrize("chunk", [1, 8, 32])
def test_chunked_ta_counts_on_tied_scores(chunk):
    rng = np.random.default_rng(43)
    T, U = _tied_problem(rng)
    idx = build_index(T)
    for u in U:
        ov, _, ostats = threshold_topk_np(T, np.asarray(idx.order_desc), u, 5)
        r = chunked_ta_topk(jnp.asarray(T), idx.order_desc,
                            idx.t_sorted_desc, idx.rank_desc,
                            jnp.asarray(u), 5, chunk=chunk)
        # integer data: arithmetic is exact, so equality is exact too
        np.testing.assert_array_equal(np.sort(np.asarray(r.values)),
                                      np.sort(ov).astype(np.float32))
        assert int(r.n_scored) == ostats.n_scored, chunk
        assert int(r.depth) == ostats.depth, chunk


def test_chunked_ta_halted_budget_is_round_granular():
    rng = np.random.default_rng(47)
    T = rng.standard_normal((300, 10)).astype(np.float32)
    idx = build_index(T)
    u = jnp.asarray(rng.standard_normal(10).astype(np.float32))
    for chunk in (1, 8, 32):
        r = chunked_ta_topk(jnp.asarray(T), idx.order_desc,
                            idx.t_sorted_desc, idx.rank_desc, u, 5,
                            chunk=chunk, max_rounds=11)
        assert int(r.depth) <= 11, chunk


# ---------------------------------------------------------------------------
# Compile cache: repeated same-shape queries must not retrace
# ---------------------------------------------------------------------------


def test_repeated_same_shape_calls_do_not_retrace():
    rng = np.random.default_rng(53)
    # shapes unique to this test (R=21, k=6): under the MODULE-LEVEL
    # argument-passing executors (DESIGN.md §10) the trace cache is
    # process-wide, so a signature another test already compiled would
    # legitimately attribute 0 traces to this context
    T = rng.standard_normal((600, 21)).astype(np.float32)
    ctx = EngineContext(T, block_size=64)
    U = jnp.asarray(rng.standard_normal((4, 21)).astype(np.float32))
    # host-only oracles never trace; dispatch engines have no executable
    engines = [e for e in list_engines() if e.has_executable]
    for eng in engines:
        eng.run(ctx, U, 6)                   # populates the cache
    warm = dict(ctx.trace_counts)
    assert all(warm.get(e.name, 0) >= 1 for e in engines)
    for _ in range(3):
        for eng in engines:
            eng.run(ctx, U, 6)
    assert ctx.trace_counts == warm          # 0 new traces after warmup
    # a second norm call specifically must not rebuild its executable
    before = ctx.trace_counts["norm"]
    get_engine("norm").run(ctx, U, 6)
    assert ctx.trace_counts["norm"] == before
    # and a SECOND context of the same M-bucket shares every trace: the
    # argument-passing engines attribute nothing to it (pallas, the one
    # closure engine, still compiles per context)
    ctx2 = EngineContext(
        rng.standard_normal((555, 21)).astype(np.float32), block_size=64)
    for eng in engines:
        if eng.run_args is not None:
            eng.run(ctx2, U, 6)
    assert ctx2.trace_counts == {}


def test_batch_bucketing_pads_and_slices():
    assert [batch_bucket(n) for n in (1, 2, 3, 5, 8, 9, 64)] == \
        [1, 2, 4, 8, 8, 16, 64]
    rng = np.random.default_rng(59)
    T = rng.standard_normal((400, 12)).astype(np.float32)
    ctx = EngineContext(T, block_size=32)
    U = jnp.asarray(rng.standard_normal((5, 12)).astype(np.float32))
    ref = np.sort(np.asarray(naive_topk(ctx.targets, U, 4).values), axis=1)
    for eng in list_engines(exact=True):
        res = eng.run(ctx, U, 4)             # 5 -> bucket 8 -> sliced to 5
        assert np.asarray(res.values).shape == (5, 4)
        np.testing.assert_allclose(np.sort(np.asarray(res.values), axis=1),
                                   ref, atol=1e-3, err_msg=eng.name)
    # buckets compile once: batch 5 and 7 share the bucket-8 executable
    warm = dict(ctx.trace_counts)
    U7 = jnp.asarray(rng.standard_normal((7, 12)).astype(np.float32))
    for eng in list_engines(exact=True):
        eng.run(ctx, U7, 4)
    assert ctx.trace_counts == warm


def test_context_warmup_precompiles():
    rng = np.random.default_rng(61)
    ctx = EngineContext(rng.standard_normal((300, 8)).astype(np.float32),
                        block_size=32)
    ctx.warmup(3, batch_sizes=(2,), engines=["norm", "bta"])
    warm = dict(ctx.trace_counts)
    U = jnp.asarray(rng.standard_normal((2, 8)).astype(np.float32))
    get_engine("norm").run(ctx, U, 3)
    get_engine("bta").run(ctx, U, 3)
    assert ctx.trace_counts == warm


# ---------------------------------------------------------------------------
# Merge network invariants (DESIGN.md §6): both inputs sorted descending
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(6))
def test_merge_topk_sorted_matches_full_sort(seed):
    rng = np.random.default_rng(seed)
    k = int(rng.integers(1, 12))
    a = np.sort(rng.standard_normal(k).astype(np.float32))[::-1].copy()
    b = np.sort(rng.standard_normal(k).astype(np.float32))[::-1].copy()
    if seed % 2:
        a[: k // 2] = float("-inf")      # partially-filled carry
    av, ai = jnp.asarray(a), jnp.arange(k, dtype=jnp.int32)
    bv, bi = jnp.asarray(b), jnp.arange(k, 2 * k, dtype=jnp.int32)
    ov, oi = merge_topk_sorted(av, ai, bv, bi, k)
    ref = np.sort(np.concatenate([a, b]))[::-1][:k]
    np.testing.assert_allclose(np.asarray(ov), ref, atol=0)
    assert np.asarray(oi).shape == (k,)


def test_merge_topk_sorted_ties_prefer_carry():
    av = jnp.asarray(np.float32([5.0, 3.0, 1.0]))
    bv = jnp.asarray(np.float32([5.0, 3.0, 2.0]))
    ai = jnp.asarray(np.int32([10, 11, 12]))
    bi = jnp.asarray(np.int32([20, 21, 22]))
    ov, oi = merge_topk_sorted(av, ai, bv, bi, 3)
    np.testing.assert_allclose(np.asarray(ov), [5.0, 5.0, 3.0])
    assert list(np.asarray(oi)) == [10, 20, 11]   # carry id first on ties


def test_pallas_engine_counts_are_block_granular():
    rng = np.random.default_rng(31)
    T = rng.standard_normal((512, 16)).astype(np.float32)
    T *= (1.0 / (1.0 + np.arange(512)))[:, None] ** 0.5
    ctx = EngineContext(T, block_size=64)
    U = jnp.asarray(rng.standard_normal((3, 16)).astype(np.float32))
    res = get_engine("pallas").run(ctx, U, 5)
    n = np.asarray(res.n_scored)
    assert np.all(n % 64 == 0)
    assert np.all(n < 512)          # the decaying catalogue prunes blocks


# ---------------------------------------------------------------------------
# Host-only reference oracles as registry engines (fagin / partial)
# ---------------------------------------------------------------------------


def test_fagin_engine_matches_ta_values():
    rng = np.random.default_rng(71)
    T = rng.standard_normal((140, 9)).astype(np.float32)
    ctx = EngineContext(T, block_size=16)
    for regime, U in _queries(rng, 3, 9).items():
        Uj = jnp.asarray(U)
        r_ta = get_engine("ta").run(ctx, Uj, 6)
        r_f = get_engine("fagin").run(ctx, Uj, 6)
        np.testing.assert_allclose(
            np.sort(np.asarray(r_f.values), axis=1),
            np.sort(np.asarray(r_ta.values), axis=1), atol=1e-4,
            err_msg=regime)


def test_partial_engine_item_counts_equal_ta():
    """Theorem 4 logic: partial TA touches exactly TA's item set, so its
    n_scored (items touched) equals the ta engine's count-faithful
    n_scored query for query."""
    rng = np.random.default_rng(73)
    T = rng.standard_normal((160, 8)).astype(np.float32)
    ctx = EngineContext(T, block_size=16)
    for regime, U in _queries(rng, 3, 8).items():
        Uj = jnp.asarray(U)
        r_ta = get_engine("ta").run(ctx, Uj, 5)
        r_p = get_engine("partial").run(ctx, Uj, 5)
        np.testing.assert_allclose(
            np.sort(np.asarray(r_p.values), axis=1),
            np.sort(np.asarray(r_ta.values), axis=1), atol=1e-4,
            err_msg=regime)
        np.testing.assert_array_equal(
            np.asarray(r_p.n_scored), np.asarray(r_ta.n_scored),
            err_msg=regime)


# ---------------------------------------------------------------------------
# CostTable persistence (ROADMAP 2b): a restarted server routes by
# measured costs before any observation, across snapshot swaps
# ---------------------------------------------------------------------------


def test_cost_table_save_load_roundtrip(tmp_path):
    from repro.core import CostTable

    t = CostTable(alpha=0.3)
    t.observe("norm", 1, "", 2e-4)
    t.observe("norm", 1, "", 1e-4)        # EWMA folds, not overwrites
    t.observe("ta", 64, "POS:5", 3e-4)
    path = tmp_path / "costs.json"
    t.save(path)
    t2 = CostTable.load(path)
    assert t2.alpha == t.alpha
    assert t2.n_observations == t.n_observations == 3
    assert t2.snapshot() == t.snapshot()
    assert t2.predict("ta", 64, "POS:5") == t.predict("ta", 64, "POS:5")
    assert t2.engine_cost("norm") == t.engine_cost("norm")
    # loaded EWMAs are live priors: new observations keep folding in
    before = t2.predict("norm", 1, "")
    t2.observe("norm", 1, "", 9e-4)
    assert t2.predict("norm", 1, "") != before


def test_loaded_cost_table_routes_before_any_measurement(tmp_path):
    """The restart story: a table measured in a previous process routes
    the auto policy from disk BEFORE this process observes anything —
    and keeps routing after a compaction swaps the snapshot (every
    compaction-built context shares the one table instance)."""
    from repro.core import CostTable, SepLRModel
    from repro.core.engines import auto_candidates, cost_label
    from repro.serving.server import TopKServer

    rng = np.random.default_rng(91)
    T = rng.standard_normal((120, 8)).astype(np.float32)
    U = rng.standard_normal((1, 8)).astype(np.float32)
    probe = EngineContext(T, block_size=16)
    # "previous process": granular measurements for every auto candidate
    # at this batch's (bucket, sign) — ta measured cheapest, which the
    # cold heuristic would never pick for a dense B=1 batch
    prev = CostTable()
    for i, name in enumerate(auto_candidates()):
        lbl = cost_label(get_engine(name), probe, U)
        cost = 1e-5 if name == "ta" else (i + 2) * 1e-3
        prev.observe(name, batch_bucket(1), lbl, cost)
    path = tmp_path / "costs.json"
    prev.save(path)

    loaded = CostTable.load(path)
    srv = TopKServer(SepLRModel(T), block_size=16, delta_capacity=8,
                     cost_table=loaded)
    assert srv.cost_table is loaded
    assert loaded.n_observations == len(auto_candidates())
    picked = select_engine(srv.ctx, U)
    assert picked.name == "ta"            # measured route, not heuristic
    # ...and the measurements survive a snapshot swap: the compaction
    # builds a NEW context around the SAME shared table
    v0 = srv.catalogue.version
    srv.add_targets(rng.standard_normal((9, 8)).astype(np.float32))
    srv.catalogue.compact(wait=True)
    assert srv.catalogue.version > v0
    assert srv.ctx.cost_table is loaded
    assert select_engine(srv.ctx, U).name == "ta"
