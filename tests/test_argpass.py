"""Argument-passing engine layer (DESIGN.md §10): compile-free compaction
and M-bucket pad-row exactness.

Two properties this file pins down:

* **Compile-freeness** — after ``TopKServer.warmup()``, a compaction
  whose new snapshot lands in a warmed M-bucket performs ZERO engine
  retraces, synchronous or background
  (``repro.core.engines.trace_totals()`` delta is 0 process-wide, and
  ``mutation_stats["engine_compiles_per_compaction"] == 0``). This is
  the whole point of passing layouts as runtime pytree args instead of
  closing over them as jit constants.
* **Pad exactness** — every argument-passing engine is exact at padded
  sizes, including the pathological all-negative-scores case (zero pad
  rows score 0 and would outrank every real item if any mask were
  missing) at every bucket boundary ``M = 2^n - 1, 2^n, 2^n + 1``, and
  the pad rows never leak into ``n_scored``/``depth``.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    EngineContext,
    SepLRModel,
    get_engine,
    m_bucket,
    trace_totals,
)
from repro.core.threshold import threshold_topk_np
from repro.serving.server import TopKServer

ARG_ENGINES = ("naive", "ta", "bta", "norm", "norm_sharded")


def _dense_oracle(T, U, k):
    s = U.astype(np.float64) @ T.astype(np.float64).T
    order = np.argsort(-s, kind="stable", axis=1)[:, :k]
    return s[np.arange(U.shape[0])[:, None], order]


def test_m_bucket_is_next_power_of_two():
    assert [m_bucket(n) for n in (1, 2, 3, 600, 1023, 1024, 1025)] == \
        [1, 2, 4, 1024, 1024, 1024, 2048]


@pytest.mark.parametrize("m", (127, 128, 129))
def test_all_negative_scores_exact_at_bucket_boundaries(m):
    """Zero pad rows score 0 — with every real score negative, a single
    missing pad mask would put a pad row (or id -1) into the top-K."""
    rng = np.random.default_rng(m)
    T = np.abs(rng.standard_normal((m, 12))).astype(np.float32)
    U = -np.abs(rng.standard_normal((5, 12))).astype(np.float32)
    ctx = EngineContext(T, block_size=32, ta_chunk=8)
    k = 6
    ref = _dense_oracle(T, U, k)
    for name in ARG_ENGINES:
        res = get_engine(name).run(ctx, jnp.asarray(U), k)
        vals = np.asarray(res.values)
        ids = np.asarray(res.indices)
        np.testing.assert_allclose(vals, ref, atol=1e-4, err_msg=name)
        assert np.all(vals < 0), name                 # no pad-zero leaked
        assert np.all((ids >= 0) & (ids < m)), name   # real catalogue ids
        assert np.all(np.asarray(res.n_scored) <= m), name


@pytest.mark.parametrize("m", (100, 129, 600))
def test_counts_sequential_faithful_under_padding(m):
    """n_scored/depth at a padded size equal the item-at-a-time oracle's
    (pad rounds must not execute) and naive's n_scored is m, not the
    bucket."""
    rng = np.random.default_rng(m + 7)
    T = rng.standard_normal((m, 8)).astype(np.float32)
    ctx = EngineContext(T, block_size=16, ta_chunk=4)
    naive_res = get_engine("naive").run(
        ctx, jnp.asarray(rng.standard_normal((3, 8)).astype(np.float32)), 4)
    assert np.all(np.asarray(naive_res.n_scored) == m)
    od = np.argsort(-T, axis=0, kind="stable").T.astype(np.int32)
    for sign in (1.0, -1.0):
        u = sign * np.abs(rng.standard_normal(8)).astype(np.float32)
        res = get_engine("ta").run(ctx, jnp.asarray(u[None, :]), 4)
        _, _, st = threshold_topk_np(T, od, u, 4)
        assert int(res.n_scored[0]) == st.n_scored, sign
        assert int(res.depth[0]) == st.depth, sign


def _mutating_server(compact_async, rng, m=700, delta_capacity=16):
    T = rng.standard_normal((m, 12)).astype(np.float32)
    srv = TopKServer(SepLRModel(jnp.asarray(T)), max_batch=8,
                     block_size=64, delta_capacity=delta_capacity,
                     compact_async=compact_async)
    srv.warmup(5, batch_sizes=(8,), engines=["norm", "bta"])
    return srv


def _stream_through_compactions(srv, rng, rounds=4):
    """Inserts + deletes sized to stay inside the boot M-bucket while
    overflowing the delta (same-bucket compactions)."""
    live = list(range(srv.catalogue.num_live))
    U = rng.standard_normal((8, 12)).astype(np.float32)
    for _ in range(rounds):
        gids = srv.add_targets(
            rng.standard_normal((10, 12)).astype(np.float32))
        live.extend(int(g) for g in gids)
        victims = [live.pop(int(rng.integers(len(live))))
                   for _ in range(10)]
        srv.delete_targets(victims)
        srv.query(U, 5, "norm")
        srv.query(U, 5, "bta")
    return U


@pytest.mark.parametrize("compact_async", (False, True))
def test_same_bucket_compaction_zero_engine_retraces(compact_async):
    rng = np.random.default_rng(11 + int(compact_async))
    srv = _mutating_server(compact_async, rng)
    bucket0 = srv.ctx.m_bucket
    before = trace_totals()
    U = _stream_through_compactions(srv, rng)
    srv.catalogue.compact(wait=True)
    srv.query(U, 5, "norm")
    srv.query(U, 5, "bta")
    ms = srv.mutation_stats
    assert ms["n_compactions"] >= 2, ms
    assert srv.ctx.m_bucket == bucket0          # same-bucket by design
    assert srv.ctx.version > 0                  # really a fresh snapshot
    # the acceptance assertion: zero engine traces anywhere in the
    # process across every compaction + post-compaction query
    assert trace_totals() == before
    assert ms["engine_compiles_per_compaction"] == 0, ms
    assert srv.ctx.trace_counts == {}           # fresh ctx compiled nothing
    # and the post-compaction results are still exact
    rows, _ = srv.catalogue.as_dense()
    ref = _dense_oracle(rows, U, 5)
    res = srv.query(U, 5, "norm")
    np.testing.assert_allclose(
        np.sort(res.values, axis=1)[:, ::-1], ref, atol=1e-4)


def test_bucket_crossing_compaction_compile_free_with_headroom():
    """Default warmup warms the NEXT M-bucket too, so a compaction that
    grows the base across its power-of-two boundary also retraces
    nothing (the streaming growth pattern)."""
    rng = np.random.default_rng(29)
    m = 250                                     # bucket 256; next 512
    T = rng.standard_normal((m, 12)).astype(np.float32)
    srv = TopKServer(SepLRModel(jnp.asarray(T)), max_batch=8,
                     block_size=64, delta_capacity=16)
    srv.warmup(5, batch_sizes=(8,), engines=["norm", "bta"])
    bucket0 = srv.ctx.m_bucket
    before = trace_totals()
    U = rng.standard_normal((8, 12)).astype(np.float32)
    for _ in range(2):                          # +32 rows: crosses 256
        srv.add_targets(rng.standard_normal((16, 12)).astype(np.float32))
        srv.query(U, 5, "norm")
    srv.catalogue.compact(wait=True)
    srv.query(U, 5, "norm")
    srv.query(U, 5, "bta")
    assert srv.ctx.m_bucket == 2 * bucket0      # really crossed
    assert srv.mutation_stats["n_compactions"] >= 1
    assert trace_totals() == before
    assert srv.mutation_stats["engine_compiles_per_compaction"] == 0
    rows, _ = srv.catalogue.as_dense()
    ref = _dense_oracle(rows, U, 5)
    res = srv.query(U, 5, "norm")
    np.testing.assert_allclose(
        np.sort(res.values, axis=1)[:, ::-1], ref, atol=1e-4)


def test_headroom_is_renewed_across_successive_bucket_crossings():
    """Each compaction build re-traces one doubling ahead (recorded in
    headroom_compiles_total, not engine_compiles_total), so the SECOND
    and later bucket crossings are as compile-free as the first."""
    rng = np.random.default_rng(37)
    # R=14 keeps the bucket signatures unique in the pytest process
    T = rng.standard_normal((100, 14)).astype(np.float32)  # bucket 128
    srv = TopKServer(SepLRModel(jnp.asarray(T)), max_batch=8,
                     block_size=32, delta_capacity=16)
    srv.warmup(5, batch_sizes=(8,), engines=["norm"])      # warms 128+256
    U = rng.standard_normal((8, 14)).astype(np.float32)
    for _ in range(12):                   # +192 rows: crosses 128 AND 256
        srv.add_targets(rng.standard_normal((16, 14)).astype(np.float32))
        srv.query(U, 5, "norm")
    srv.catalogue.compact(wait=True)
    srv.query(U, 5, "norm")
    ms = srv.mutation_stats
    assert srv.ctx.m_bucket >= 512        # two crossings happened
    assert ms["n_compactions"] >= 2
    assert ms["engine_compiles_per_compaction"] == 0, ms
    assert ms["headroom_compiles_total"] > 0, ms   # renewals really traced
    rows, _ = srv.catalogue.as_dense()
    ref = _dense_oracle(rows, U, 5)
    res = srv.query(U, 5, "norm")
    np.testing.assert_allclose(
        np.sort(res.values, axis=1)[:, ::-1], ref, atol=1e-4)


def test_unwarmed_bucket_growth_pays_compiles_on_the_build():
    """Without headroom warming, a bucket-crossing compaction DOES trace —
    but the traces land in the build (recorded in engine_compiles_total),
    never unaccounted."""
    rng = np.random.default_rng(31)
    # R=13 keeps this signature unique in the process: the module-level
    # executors cache process-wide, so shapes another test traced at the
    # 512 bucket would make the build legitimately compile-free
    T = rng.standard_normal((250, 13)).astype(np.float32)
    srv = TopKServer(SepLRModel(jnp.asarray(T)), max_batch=8,
                     block_size=64, delta_capacity=16)
    srv.warmup(5, batch_sizes=(8,), engines=["norm"],
               m_buckets=(256,))                # current bucket ONLY
    srv.add_targets(rng.standard_normal((16, 13)).astype(np.float32))
    srv.catalogue.compact(wait=True)            # crosses into 512
    ms = srv.mutation_stats
    assert srv.ctx.m_bucket == 512
    assert ms["engine_compiles_total"] > 0
    assert ms["compaction_s_total"] > 0.0
