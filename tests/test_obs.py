"""Observability layer (DESIGN.md §14): registry/trace/journal unit
tests, the ServeStats façade contract, thread-safety under concurrent
recording + compaction, and the end-to-end span↔journal join."""

import threading

import numpy as np
import pytest

from repro import obs
from repro.core import SepLRModel, certificate_gaps, faults
from repro.core.engines import batch_bucket
from repro.serving.pipeline import AsyncTopKServer
from repro.serving.server import LATENCY_RING, ServeStats, TopKServer


@pytest.fixture(autouse=True)
def _clean_obs():
    """Every test sees empty default stores and an enabled layer."""
    obs.reset()
    obs.set_enabled(True)
    obs.TRACER.sample_rate = 1.0
    yield
    obs.reset()
    obs.set_enabled(True)


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_counter_labels_and_total():
    reg = obs.MetricsRegistry()
    c = reg.counter("t_requests_total", "x", labels=("engine",))
    c.inc(engine="bta")
    c.inc(2, engine="norm")
    assert c.value(engine="bta") == 1
    assert c.value(engine="norm") == 2
    assert c.value(engine="nope") == 0
    assert c.total() == 3


def test_registry_get_or_create_rejects_mismatch():
    reg = obs.MetricsRegistry()
    reg.counter("t_thing", "x", labels=("a",))
    assert reg.counter("t_thing", "x", labels=("a",)) is reg.get("t_thing")
    with pytest.raises(ValueError):
        reg.counter("t_thing", "x", labels=("b",))
    with pytest.raises(ValueError):
        reg.gauge("t_thing", "x", labels=("a",))
    with pytest.raises(ValueError):
        reg.counter("bad name!", "x")


def test_histogram_ring_percentile_matches_numpy():
    reg = obs.MetricsRegistry()
    h = reg.histogram("t_lat_us", "x", buckets=obs.LATENCY_BUCKETS_US,
                      ring=64)
    rng = np.random.default_rng(3)
    vals = rng.lognormal(5, 2, size=200)
    for v in vals:
        h.observe(float(v))
    window = np.asarray(list(h.ring()))
    assert len(window) == 64
    for q in (50, 95, 99):
        assert h.percentile(q) == pytest.approx(
            float(np.percentile(window, q)))
    assert h.count() == 200
    assert h.mean() == pytest.approx(float(vals.mean()))


def test_histogram_bucketless_series_and_empty():
    reg = obs.MetricsRegistry()
    h = reg.histogram("t_noring", "x", buckets=(1.0, 10.0, 100.0))
    assert h.percentile(50) == 0.0
    h.observe(5.0)
    assert h.percentile(50) == 10.0  # bucket upper-bound estimate
    with pytest.raises(ValueError):
        h.ring()   # no ring kept


def test_snapshot_validates_and_prom_parses():
    obs.on_batch_served("bta", 4, 100, 40, 1000, 250.0, "nonneg")
    obs.on_degradation("bta", "shed")
    obs.on_compaction("success", duration_s=0.01, version=1, epoch=2)
    snap = obs.REGISTRY.snapshot()
    obs.validate_snapshot(snap)          # raises on violation
    samples = obs.parse_prom_text(obs.REGISTRY.render_prom())
    assert samples['repro_queries_total{engine="bta"}'] == 4
    assert samples["repro_shed_total"] == 1
    assert samples["repro_compaction_seconds_count"] == 1
    # histogram cumulative buckets present
    assert any(k.startswith("repro_batch_latency_us_bucket")
               for k in samples)


def test_snapshot_schema_rejects_garbage():
    with pytest.raises(ValueError):
        obs.validate_snapshot({"nope": 1})
    with pytest.raises(ValueError):
        obs.validate_snapshot(
            {"metrics": {"m": {"kind": "sundial", "help": "",
                               "labels": [], "series": []}}})


def test_disable_switch_stops_recording():
    obs.set_enabled(False)
    obs.on_batch_served("bta", 4, 100, 40, 1000, 250.0)
    obs.on_fault_fired("compaction.build")
    assert obs.QUERIES.total() == 0
    assert len(obs.JOURNAL) == 0
    assert obs.TRACER.start_trace("x") is None
    obs.set_enabled(True)
    obs.on_batch_served("bta", 4, 100, 40, 1000, 250.0)
    assert obs.QUERIES.total() == 4


# ---------------------------------------------------------------------------
# trace spans + event journal
# ---------------------------------------------------------------------------

def test_tracer_every_nth_sampling_is_deterministic():
    tr = obs.Tracer(capacity=16, sample_rate=0.25)
    kept = [tr.start_trace("t") is not None for _ in range(100)]
    assert sum(kept) == 25
    tr2 = obs.Tracer(capacity=16, sample_rate=0.25)
    assert kept == [tr2.start_trace("t") is not None for _ in range(100)]


def test_trace_tree_and_store_bound():
    tr = obs.Tracer(capacity=2)
    for i in range(3):
        t = tr.start_trace("req", k=i)
        t.span("queue_wait", start=0.0, end=0.5)
        t.span("device", start=0.5, end=1.0, engine="bta")
        t.finish()
    done = tr.traces()
    assert len(done) == 2          # bounded store evicted the oldest
    tree = done[-1].format_tree()
    assert "queue_wait" in tree and "engine=bta" in tree
    assert done[-1].find("device").duration_us == pytest.approx(5e5)


def test_journal_filter_tail_and_capacity():
    j = obs.EventJournal(capacity=8)
    for i in range(12):
        j.emit("tick", i=i, kind_field="x")
    assert len(j) == 8
    assert [e.fields["i"] for e in j.tail(3)] == [9, 10, 11]
    assert len(j.events("tick", i=10)) == 1
    assert j.counts() == {"tick": 12}   # lifetime, survives eviction
    # seq increases across eviction; as_dict round-trips
    d = j.tail(1)[0].as_dict()
    assert d["kind"] == "tick" and d["seq"] == 12


# ---------------------------------------------------------------------------
# ServeStats façade + mutation_stats schema
# ---------------------------------------------------------------------------

def test_servestats_facade_unchanged():
    s = ServeStats()
    for i in range(LATENCY_RING + 57):
        s.lat_us_ring.append(float(i))   # legacy direct-append path
    assert len(s.lat_us_ring) == LATENCY_RING
    want = np.asarray(s.lat_us_ring)
    assert s.p50_us == pytest.approx(float(np.percentile(want, 50)))
    assert s.p99_us == pytest.approx(float(np.percentile(want, 99)))
    s.record_request_latency(100.0)
    s.record_request_latency(300.0)
    assert s.req_p50_us == pytest.approx(200.0)
    assert len(s.req_lat_us_ring) == 2
    s.record_batch(4, 100, 40, 0.001, 8, "nonneg")
    assert (s.n_queries, s.n_scored, s.depth_sum, s.delta_scored) == \
        (4, 100, 40, 8)
    assert s.sign_batches == {"nonneg": 1}
    assert s.scores_per_query == 25.0
    s.bump_degradation("shed")
    s.note_uncertified(2)
    assert s.degradations == {"shed": 1} and s.n_uncertified == 2


def test_servestats_records_when_obs_disabled():
    # the façade histograms are STANDALONE instruments: the obs master
    # switch must not dark the server's own serving stats (they are the
    # pre-§14 baseline behaviour, and the overhead bench's off-mode
    # still reads them)
    obs.set_enabled(False)
    s = ServeStats()
    s.record_batch(1, 10, 5, 0.001)
    s.record_request_latency(42.0)
    assert s.n_queries == 1 and len(s.lat_us_ring) == 1
    assert s.req_p50_us == pytest.approx(42.0)


def test_mutation_stats_matches_declared_schema():
    rng = np.random.default_rng(0)
    T = rng.standard_normal((193, 7)).astype(np.float32)
    srv = TopKServer(SepLRModel(T), delta_capacity=8)
    ms = srv.mutation_stats
    assert set(ms) == set(obs.MUTATION_STATS_SCHEMA)
    for key, field in obs.MUTATION_STATS_SCHEMA.items():
        assert isinstance(ms[key], field.type), key
        assert field.doc   # every key documented
    # drift in either direction raises
    with pytest.raises(KeyError):
        obs.build_mutation_stats({**ms, "surprise": 1})
    short = dict(ms)
    short.popitem()
    with pytest.raises(KeyError):
        obs.build_mutation_stats(short)


# ---------------------------------------------------------------------------
# thread-safety hammer
# ---------------------------------------------------------------------------

def test_concurrent_recording_loses_nothing():
    """N threads hammer a ServeStats + registry counters while another
    thread mutates/compacts the catalogue (cache invalidations, epoch
    bumps) and a reader spins percentiles: exact totals, no
    exceptions."""
    rng = np.random.default_rng(1)
    T = rng.standard_normal((211, 7)).astype(np.float32)
    srv = TopKServer(SepLRModel(T), delta_capacity=8)
    s = ServeStats()
    c = obs.REGISTRY.counter("t_hammer_total", "x", labels=("t",))
    N_THREADS, N_ITER = 8, 400
    errors = []
    go = threading.Event()

    def writer(tid):
        go.wait()
        try:
            for i in range(N_ITER):
                s.record_batch(1, 10, 5, 1e-6, 0, "s%d" % (i % 3))
                s.record_request_latency(float(i))
                c.inc(t=str(tid))
        except BaseException as e:   # noqa: BLE001 — the assertion
            errors.append(e)

    def reader():
        go.wait()
        try:
            for _ in range(N_ITER):
                s.p99_us, s.req_p50_us, s.scores_per_query
                obs.REGISTRY.render_prom()
        except BaseException as e:   # noqa: BLE001 — the assertion
            errors.append(e)

    def mutator():
        go.wait()
        try:
            for i in range(24):
                srv.add_targets(rng.standard_normal((4, 7))
                                .astype(np.float32))
        except BaseException as e:   # noqa: BLE001 — the assertion
            errors.append(e)

    threads = [threading.Thread(target=writer, args=(t,))
               for t in range(N_THREADS)]
    threads += [threading.Thread(target=reader),
                threading.Thread(target=mutator)]
    for t in threads:
        t.start()
    go.set()
    for t in threads:
        t.join()
    assert not errors
    assert s.n_queries == N_THREADS * N_ITER
    assert s.n_scored == 10 * N_THREADS * N_ITER
    assert sum(s.sign_batches.values()) == N_THREADS * N_ITER
    assert c.total() == N_THREADS * N_ITER
    for tid in range(N_THREADS):
        assert c.value(t=str(tid)) == N_ITER
    assert srv.mutation_stats["n_compactions"] >= 1
    assert obs.CACHE_INVALIDATIONS.total() == 0  # no cache attached
    assert len(obs.JOURNAL.events("compaction.success")) >= 1


# ---------------------------------------------------------------------------
# live certificate metrics pinned against certificate_gaps
# ---------------------------------------------------------------------------

def test_certificate_metrics_match_ground_truth():
    rng = np.random.default_rng(5)
    T = rng.standard_normal((223, 7)).astype(np.float32)
    srv = TopKServer(SepLRModel(T))
    U = rng.standard_normal((4, 7)).astype(np.float32)
    budget = 3
    res = srv.query(U, k=5, method="norm", budget=budget)
    gaps = np.asarray(certificate_gaps(res))
    valid = np.asarray(res.indices) >= 0
    unc = np.logical_and(gaps > 0, np.isfinite(gaps))
    want_frac = 1.0 - unc.sum() / max(valid.sum(), 1)
    bucket = str(batch_bucket(budget))
    assert obs.CERTIFIED_FRACTION.count(
        engine="norm", budget_bucket=bucket) == 1
    assert obs.CERTIFIED_FRACTION.sum(
        engine="norm", budget_bucket=bucket) == pytest.approx(want_frac)
    if unc.any():
        want_gap = float(gaps[unc].mean())
        assert obs.UNCERTIFIED_GAP.sum(
            engine="norm", budget_bucket=bucket) == \
            pytest.approx(want_gap, rel=1e-5)
        n_unc_q = int(np.sum(np.any(unc, axis=1)))
        assert obs.UNCERTIFIED.value(engine="norm") == n_unc_q
        assert srv.stats["norm"].n_uncertified == n_unc_q


# ---------------------------------------------------------------------------
# fault seams + end-to-end span/journal join
# ---------------------------------------------------------------------------

def test_fault_firing_emits_event():
    with faults.injected("compaction.build", error=None, times=1):
        assert faults.fire("compaction.build")
    assert obs.FAULTS_FIRED.value(point="compaction.build") == 1
    ev = obs.JOURNAL.events("fault.fired")
    assert ev and ev[-1].fields["point"] == "compaction.build"


def test_async_request_span_joins_compaction_event():
    """The acceptance trace: one async request's span tree names the
    engine, the cost-table entry, queue/coalesce/device stage
    durations, and the (version, epoch) it ran against — and that
    version joins to the compaction.success journal event that
    produced the snapshot."""
    rng = np.random.default_rng(9)
    T = rng.standard_normal((227, 7)).astype(np.float32)
    with AsyncTopKServer(SepLRModel(T), max_batch=8, delta_capacity=8,
                         method="bta") as srv:
        srv.warmup(4)
        obs.reset()   # drop warmup noise; keep the layer on
        # force a synchronous compaction: >capacity appends
        srv.add_targets(rng.standard_normal((9, 7)).astype(np.float32))
        comp = obs.JOURNAL.events("compaction.success")
        assert comp, "mutation burst must have compacted"
        version = comp[-1].fields["version"]
        h = srv.submit(rng.standard_normal(7).astype(np.float32), 4)
        h.result(timeout=30)
        traces = obs.TRACER.traces()
        assert traces
        t = traces[-1]
        # the stage ladder, in order, every span closed
        names = [s.name for s in t.spans]
        for stage in ("queue_wait", "coalesce", "route", "dispatch",
                      "device", "harvest", "merge"):
            assert stage in names, stage
        assert all(s.t_end is not None for s in t.spans)
        dev = t.find("device")
        assert dev.attrs["engine"] == "bta"
        assert "bta" in t.find("route").attrs["cost_entry"]
        assert t.find("queue_wait").duration_us >= 0.0
        # the JOIN: the span ran against the snapshot the journal's
        # compaction.success event says it produced
        assert dev.attrs["version"] == version
        assert t.root.attrs["version"] == version
        joined = obs.JOURNAL.events("compaction.success",
                                    version=dev.attrs["version"])
        assert len(joined) == 1
        # the registry saw the same request on its always-on counters
        assert obs.QUERIES.value(engine="bta") >= 1
        assert obs.REQUEST_LATENCY.count(engine="bta") >= 1


def test_async_request_span_joins_fold_event():
    """Same join discipline across the LSM ladder: an L0 -> L1 fold
    journals compaction.fold_l1 with the SAME (version, epoch) join keys
    as compaction.success, and a traced request that ran against the
    folded catalogue joins to it. A fold moves rows without changing
    visible contents, so it must NOT bump the epoch — the request's
    device span carries the very same (version, epoch) the fold event
    recorded."""
    from repro.core import ShardedLsmCatalogue

    rng = np.random.default_rng(23)
    T = rng.standard_normal((113, 7)).astype(np.float32)
    with AsyncTopKServer(SepLRModel(T), max_batch=8, delta_capacity=8,
                         method="bta", n_shards=4) as srv:
        assert isinstance(srv.server.catalogue, ShardedLsmCatalogue)
        srv.warmup(4)
        obs.reset()   # drop warmup noise; keep the layer on
        # stage rows below capacity, then compact: the ladder seals the
        # delta and folds it into L1 inline (no full rebuild, no build
        # thread) — and, because a fold changes no visible contents, no
        # epoch bump either
        srv.add_targets(rng.standard_normal((5, 7)).astype(np.float32))
        srv.server.catalogue.compact(wait=True)
        folds = obs.JOURNAL.events("compaction.fold_l1")
        assert folds, "overflow must have folded, not rebuilt"
        assert not obs.JOURNAL.events("compaction.success")
        ev = folds[-1].fields
        assert ev["rows_folded"] >= 1 and ev["l1_rows"] >= 1
        h = srv.submit(rng.standard_normal(7).astype(np.float32), 4)
        h.result(timeout=30)
        t = obs.TRACER.traces()[-1]
        dev = t.find("device")
        # the JOIN, both keys: the request ran against exactly the
        # (version, epoch) the fold event was journalled under
        assert dev.attrs["version"] == ev["version"]
        assert dev.attrs["epoch"] == ev["epoch"]
        joined = obs.JOURNAL.events("compaction.fold_l1",
                                    version=dev.attrs["version"],
                                    epoch=dev.attrs["epoch"])
        assert joined and joined[-1].fields == ev
