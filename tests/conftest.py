"""Process-level test environment knobs (imported before any test module).

XLA:CPU's parallel LLVM codegen (default split count 32) intermittently
segfaults inside ``backend_compile`` on jaxlib 0.4.3x once a long-lived
process has accumulated a few hundred compiled executables — the full
tier-1 suite reliably hit it in the late warmup-heavy tests while every
file-subset run passed. Serialising codegen removes the crash; on the
1-core containers this suite targets it costs nothing (the split only
helps when spare cores can compile modules concurrently), and on
multi-core CI it adds a little compile time to a suite dominated by
execution. Appended so job-level ``XLA_FLAGS`` (e.g. the multidevice
job's ``--xla_force_host_platform_device_count=8``) are preserved.
"""

import os

_FLAG = "--xla_cpu_parallel_codegen_split_count=1"
if _FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " " + _FLAG).strip()
