"""Multi-device tests — run in a subprocess with 8 fake host devices so the
main pytest process keeps its single-device view.

CI runs this file on an 8-virtual-device box (``tier1-multidevice`` job,
``XLA_FLAGS=--xla_force_host_platform_device_count=8``) with a jax that
has the explicit-mesh APIs, so nothing here silently skips there. The
``needs_explicit_mesh`` tests skip on older jax; the ``norm_sharded``
tests run EVERYWHERE — they only need ``Mesh`` + ``shard_map``, which
``repro.core.sharded.compat_shard_map`` bridges across jax versions.
"""

import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest

needs_explicit_mesh = pytest.mark.skipif(
    not (hasattr(jax, "set_mesh") and hasattr(jax.sharding, "AxisType")),
    reason="needs the explicit-mesh APIs (jax.set_mesh / sharding.AxisType) "
           "of newer jax; this interpreter's jax predates them")

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, timeout=560):
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env, cwd=REPO)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


@needs_explicit_mesh
def test_sharded_topk_exact_all_variants():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.core import (naive_topk, sharded_naive_topk,
                                sharded_blocked_topk, hierarchical_merge_topk)
        from repro.core.index import build_index

        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        rng = np.random.default_rng(1)
        M, R, K, B = 1024, 32, 10, 4
        T = rng.standard_normal((M, R)).astype(np.float32)
        U = rng.standard_normal((B, R)).astype(np.float32)
        nv = np.sort(np.asarray(naive_topk(jnp.asarray(T), jnp.asarray(U), K).values), axis=1)

        f = sharded_naive_topk(mesh, P("data", None), ("data",))
        with jax.set_mesh(mesh):
            res = f(jnp.asarray(T), jnp.asarray(U), K)
        assert np.allclose(np.sort(np.asarray(res.values), axis=1), nv, atol=1e-5)

        m_local = M // 8
        orders, tsorts = [], []
        for s in range(8):
            ix = build_index(T[s*m_local:(s+1)*m_local])
            orders.append(np.asarray(ix.order_desc)); tsorts.append(np.asarray(ix.t_sorted_desc))
        g = sharded_blocked_topk(mesh, (P("data", None), P(None, "data"),
                                        P(None, "data")), ("data",))
        with jax.set_mesh(mesh):
            res2 = g(jnp.asarray(T), jnp.asarray(np.concatenate(orders, 1)),
                     jnp.asarray(np.concatenate(tsorts, 1)), jnp.asarray(U), K, 16)
        assert np.allclose(np.sort(np.asarray(res2.values), axis=1), nv, atol=1e-5)

        mesh2 = jax.make_mesh((2, 4), ("pod", "data"),
                              axis_types=(jax.sharding.AxisType.Auto,)*2)
        h = hierarchical_merge_topk(mesh2, P(("pod", "data"), None),
                                    ("data",), ("pod",))
        with jax.set_mesh(mesh2):
            res3 = h(jnp.asarray(T), jnp.asarray(U), K)
        assert np.allclose(np.sort(np.asarray(res3.values), axis=1), nv, atol=1e-5)
        print("SHARDED_OK")
    """)
    assert "SHARDED_OK" in out


@needs_explicit_mesh
def test_topk_logits_sharded_vocab():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.models.transformer import topk_logits
        from repro.models.common import MeshRules
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        rng = np.random.default_rng(0)
        hidden = jnp.asarray(rng.standard_normal((4, 32)).astype(np.float32))
        unembed = jnp.asarray(rng.standard_normal((32, 128)).astype(np.float32))
        ref = np.sort(np.asarray(hidden @ unembed), axis=1)[:, ::-1][:, :7]
        with jax.set_mesh(mesh):
            vals, idx = topk_logits(hidden, unembed, 7, MeshRules())
        assert np.allclose(np.asarray(vals), ref, atol=1e-4)
        print("TOPK_LOGITS_OK")
    """)
    assert "TOPK_LOGITS_OK" in out


@needs_explicit_mesh
def test_compressed_allreduce_pod_axis():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.train.compression import make_compressed_allreduce
        mesh = jax.make_mesh((8,), ("pod",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        rng = np.random.default_rng(0)
        g = {"w": jnp.asarray(rng.standard_normal((8, 64)).astype(np.float32))}
        e = jax.tree_util.tree_map(jnp.zeros_like, g)
        fn = make_compressed_allreduce(mesh, "pod")
        with jax.set_mesh(mesh):
            mean_g, new_e = fn(g, e)
        true = jnp.mean(g["w"], axis=0)
        rel = float(jnp.max(jnp.abs(mean_g["w"] - true)) / jnp.max(jnp.abs(true)))
        assert rel < 0.05, rel
        print("COMPRESS_OK")
    """)
    assert "COMPRESS_OK" in out


@pytest.mark.slow
@needs_explicit_mesh
def test_dryrun_cells_tiny_mesh():
    """Integration: the dry-run machinery lowers+compiles representative
    cells of all three families on a tiny in-test mesh."""
    env = dict(os.environ, REPRO_DRYRUN_DEVICES="8",
               PYTHONPATH=os.path.join(REPO, "src"))
    for arch, shape in [("fm", "retrieval_cand"), ("pna", "molecule"),
                        ("stablelm-3b", "decode_32k")]:
        r = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
             "--shape", shape, "--mesh", "tiny-multi", "--out",
             "/tmp/dryrun_test"],
            capture_output=True, text=True, timeout=560, env=env, cwd=REPO)
        assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
        rec = json.load(open(f"/tmp/dryrun_test/{arch}__{shape}__tiny-multi.json"))
        assert rec["status"] == "ok"
        assert rec["roofline"]["flops"] > 0


def test_norm_sharded_identical_topk_on_8_device_mesh():
    """Acceptance: the norm_sharded engine returns the IDENTICAL top-K set
    as the single-host norm engine on an 8-virtual-device CPU mesh,
    through the engine registry (version-agnostic: compat_shard_map)."""
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import EngineContext, get_engine
        assert len(jax.devices()) == 8, jax.devices()
        rng = np.random.default_rng(3)
        M, R, K = 4096, 16, 10
        T = rng.standard_normal((M, R)).astype(np.float32)
        T *= (1.0 / np.sqrt(1.0 + np.arange(M)))[:, None].astype(np.float32)
        ctx = EngineContext(T, block_size=128)
        lay = ctx.layout("norm_sharded")
        assert lay.n_shards == 8
        for seed in range(3):
            U = jnp.asarray(np.random.default_rng(seed).standard_normal(
                (6, R)).astype(np.float32))
            r_norm = get_engine("norm").run(ctx, U, K)
            r_sh = get_engine("norm_sharded").run(ctx, U, K)
            # identical SET: same sorted values and same id set per query
            np.testing.assert_allclose(
                np.sort(np.asarray(r_sh.values), axis=1),
                np.sort(np.asarray(r_norm.values), axis=1), atol=1e-4)
            for b in range(6):
                assert (set(np.asarray(r_sh.indices)[b].tolist())
                        == set(np.asarray(r_norm.indices)[b].tolist())), b
            # cross-shard tightening prunes: the sharded scan's quantum is
            # one block per shard, so it pays at most ~2 dealt block-rounds
            # over the single-host depth — and never degrades to full scan
            assert np.all(np.asarray(r_sh.n_scored)
                          <= np.asarray(r_norm.n_scored) + 2 * 8 * 128)
            assert np.all(np.asarray(r_sh.n_scored) < M)
        print("NORM_SHARDED_OK")
    """)
    assert "NORM_SHARDED_OK" in out


def test_norm_sharded_flat_norms_stay_exact_multidevice():
    """Constant-norm catalogue: no shard can prune — the sharded scan must
    degrade to a full dealt scan, not a wrong answer."""
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import EngineContext, get_engine, naive_topk
        rng = np.random.default_rng(7)
        T = rng.standard_normal((1000, 12)).astype(np.float32)
        T /= np.linalg.norm(T, axis=1, keepdims=True)
        ctx = EngineContext(T, block_size=64)
        U = jnp.asarray(rng.standard_normal((4, 12)).astype(np.float32))
        ref = np.sort(np.asarray(naive_topk(ctx.targets, U, 5).values), axis=1)
        res = get_engine("norm_sharded").run(ctx, U, 5)
        np.testing.assert_allclose(np.sort(np.asarray(res.values), axis=1),
                                   ref, atol=1e-4)
        print("FLAT_OK")
    """)
    assert "FLAT_OK" in out
