"""Per-arch smoke tests: REDUCED config of each assigned architecture runs
one forward + one train step on CPU; asserts output shapes and no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, get_arch
from repro.data.synthetic import molecule_batch, random_graph
from repro.models import gnn as gnn_mod
from repro.models import recsys as recsys_mod
from repro.models import transformer as tf_mod
from repro.models.common import count_params
from repro.train.optimizer import OptimizerConfig, apply_updates, init_state

LM_ARCHS = [a for a, s in REGISTRY.items() if s.family == "lm"]
RECSYS_ARCHS = [a for a, s in REGISTRY.items() if s.family == "recsys"]
OPT = OptimizerConfig(kind="adamw", lr=1e-3, warmup_steps=1, total_steps=10)


def _one_step(loss_fn, params, batch):
    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        params, batch)
    new_p, _, om = apply_updates(OPT, params, grads, init_state(OPT, params))
    gn = float(om["grad_norm"])
    return float(loss), gn, new_p


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_lm_smoke(arch_id):
    cfg = get_arch(arch_id).make_smoke_config()
    params = tf_mod.init_params(cfg, jax.random.PRNGKey(0))
    assert count_params(params) == cfg.param_count()
    rng = np.random.default_rng(0)
    B, S = 2, 32
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
    }
    hidden, aux = tf_mod.forward(params, batch["tokens"], cfg)
    assert hidden.shape == (B, S, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(hidden.astype(jnp.float32))))
    loss, gn, _ = _one_step(lambda p, b: tf_mod.loss_fn(p, b, cfg), params, batch)
    assert np.isfinite(loss) and np.isfinite(gn) and gn > 0
    # decode step with the SEP-LR top-K head
    cache = tf_mod.init_kv_cache(cfg, B, S + 4)
    (vals, idx), cache = tf_mod.serve_step(
        params, cache, batch["tokens"][:, :1], 0, cfg, top_k=5)
    assert vals.shape == (B, 5) and idx.shape == (B, 5)
    assert bool(jnp.all(jnp.isfinite(vals)))
    assert bool(jnp.all((idx >= 0) & (idx < cfg.vocab_size)))


@pytest.mark.parametrize("arch_id", RECSYS_ARCHS)
def test_recsys_smoke(arch_id):
    cfg = get_arch(arch_id).make_smoke_config()
    params = recsys_mod.init_params(cfg, jax.random.PRNGKey(0))
    assert count_params(params) == cfg.param_count()
    rng = np.random.default_rng(0)
    B = 32
    batch = {
        "dense": jnp.asarray(rng.standard_normal((B, cfg.n_dense)), jnp.float32),
        "sparse": jnp.asarray(rng.integers(0, cfg.vocab_per_field,
                                           (B, cfg.n_sparse)), jnp.int32),
        "label": jnp.asarray(rng.integers(0, 2, (B,)), jnp.float32),
    }
    logits = recsys_mod.forward(params, batch, cfg)
    assert logits.shape == (B,)
    assert bool(jnp.all(jnp.isfinite(logits)))
    loss, gn, _ = _one_step(lambda p, b: recsys_mod.loss_fn(p, b, cfg),
                            params, batch)
    assert np.isfinite(loss) and gn > 0
    # retrieval head produces a query embedding
    u = recsys_mod.query_tower(params, batch, cfg)
    assert u.shape == (B, cfg.embed_dim)


def test_pna_smoke_node_task():
    cfg = get_arch("pna").make_smoke_config()
    params = gnn_mod.init_params(cfg, jax.random.PRNGKey(0))
    from repro.models.common import count_params
    assert count_params(params) == cfg.param_count()
    graph = {k: jnp.asarray(v) for k, v in
             random_graph(np.random.default_rng(0), 64, 256, cfg.d_in,
                          cfg.n_classes).items()}
    logits = gnn_mod.forward(params, graph, cfg)
    assert logits.shape == (64, cfg.n_classes)
    assert bool(jnp.all(jnp.isfinite(logits)))
    loss, gn, _ = _one_step(lambda p, g: gnn_mod.loss_fn(p, g, cfg),
                            params, graph)
    assert np.isfinite(loss) and gn > 0


def test_pna_smoke_graph_task():
    import dataclasses
    cfg = dataclasses.replace(get_arch("pna").make_smoke_config(),
                              task="graph", d_in=6, n_classes=2)
    params = gnn_mod.init_params(cfg, jax.random.PRNGKey(0))
    g = molecule_batch(np.random.default_rng(0), 8, 10, 20, 6, 2)
    ng = g.pop("n_graphs")
    graph = {k: jnp.asarray(v) for k, v in g.items()}
    graph["n_graphs"] = ng
    logits = gnn_mod.forward(params, graph, cfg)
    assert logits.shape == (8, 2)
    loss, m = gnn_mod.loss_fn(params, graph, cfg)
    assert np.isfinite(float(loss))


def test_pna_neighbor_sampler_covers_seeds():
    rng = np.random.default_rng(1)
    es = rng.integers(0, 200, 3000).astype(np.int32)
    ed = rng.integers(0, 200, 3000).astype(np.int32)
    sampler = gnn_mod.NeighborSampler(es, ed, 200)
    seeds = np.arange(32)
    sub = sampler.sample(seeds, (15, 10))
    assert set(seeds) <= set(sub["node_ids"].tolist())
    feats = rng.standard_normal((200, 8)).astype(np.float32)
    labels = rng.integers(0, 3, 200).astype(np.int32)
    padded = gnn_mod.pad_subgraph(sub, feats, labels, 4096, 8192)
    # edges reference only in-range nodes
    assert padded["edge_src"].max() < 4096
    assert padded["node_mask"].sum() >= len(seeds) * 0.9


def test_moe_load_balance_and_dropping():
    """MoE aux loss ~1 for uniform routing; capacity drops are bounded."""
    from repro.models.moe import init_moe, moe_ffn
    key = jax.random.PRNGKey(0)
    params = init_moe(key, 32, 64, 8)
    x = jax.random.normal(jax.random.PRNGKey(1), (256, 32))
    out, aux = moe_ffn(params, x, top_k=2, capacity_factor=1.25)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))
    assert 0.5 < float(aux["aux_loss"]) < 4.0
    assert float(aux["drop_rate"]) < 0.5
