"""Budgeted exact-prefix queries and their certificates (DESIGN.md §12).

The property this file pins down: a budget-capped scan returns, besides
the usual top-K, an ``upper`` bound on every item it did NOT enumerate —
and every slot whose certificate gap (``upper - value``) is <= 0 is
PROVABLY a member of the true top-K, at the true rank. Validated against
the faithful-TA / dense oracles at every tested budget, for both sign
patterns (all-positive and mixed-sign queries, the batched list scan's
compile-specialisation axis) and across the M-bucket boundaries
``2^n - 1, 2^n, 2^n + 1``. Also pinned: budgeted variants join the
argument-passing compile contract (DESIGN.md §10) — warmed budgets stay
compile-free across compactions.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    EngineContext,
    SepLRModel,
    certificate_gaps,
    certified_counts,
    get_engine,
    trace_totals,
)
from repro.core.naive import TopKResult
from repro.serving.server import TopKServer

BUDGET_ENGINES = ("ta", "bta", "norm")
K = 6


def _dense_oracle(T, U, k):
    s = U.astype(np.float64) @ T.astype(np.float64).T
    order = np.argsort(-s, kind="stable", axis=1)[:, :k]
    return (s[np.arange(U.shape[0])[:, None], order], order)


def _queries(rng, n, r, sign):
    U = rng.standard_normal((n, r)).astype(np.float32)
    return np.abs(U) if sign == "pos" else U


@pytest.mark.parametrize("m", (1023, 1024, 1025))
@pytest.mark.parametrize("sign", ("pos", "mixed"))
def test_certified_slots_are_the_true_topk_prefix(m, sign):
    """At EVERY budget, the certified slots (gap <= 0) match the true
    top-K prefix exactly — values AND membership — for every
    budget-capable engine; certification is monotone within a result
    (a prefix, never a scattered subset)."""
    rng = np.random.default_rng(m + (0 if sign == "pos" else 1))
    T = rng.standard_normal((m, 12)).astype(np.float32)
    U = _queries(rng, 6, 12, sign)
    ctx = EngineContext(T, block_size=64, ta_chunk=16)
    ref_vals, _ = _dense_oracle(T, U, K)
    for name in BUDGET_ENGINES:
        eng = get_engine(name)
        for budget in (1, 4, 16, 64, 10 ** 9):
            res = eng.run(ctx, jnp.asarray(U), K, budget=budget)
            assert res.upper is not None, (name, budget)
            gaps = np.asarray(certificate_gaps(res))
            counts = np.asarray(certified_counts(res))
            vals = np.asarray(res.values)
            for q in range(U.shape[0]):
                certified = gaps[q] <= 0
                c = int(counts[q])
                # certified slots form a PREFIX (values sorted desc ->
                # gaps ascending)
                assert np.all(certified[:c]) and not np.any(certified[c:]), \
                    (name, budget, q, gaps[q])
                # ... and the prefix is the true top-K prefix
                np.testing.assert_allclose(
                    vals[q, :c], ref_vals[q, :c], atol=1e-4,
                    err_msg=f"{name} budget={budget} query={q}")
            # an effectively unlimited budget must certify everything
            if budget == 10 ** 9:
                assert np.all(counts == K), (name, counts)


@pytest.mark.parametrize("name", ("naive",) + BUDGET_ENGINES)
def test_exact_runs_are_fully_certified(name):
    """Without a budget every engine's result is exact, and its
    certificate says so: every slot's gap <= 0."""
    rng = np.random.default_rng(7)
    T = rng.standard_normal((400, 12)).astype(np.float32)
    U = rng.standard_normal((4, 12)).astype(np.float32)
    ctx = EngineContext(T, block_size=64, ta_chunk=16)
    res = get_engine(name).run(ctx, jnp.asarray(U), K)
    assert np.all(np.asarray(certified_counts(res)) == K)
    ref_vals, _ = _dense_oracle(T, U, K)
    np.testing.assert_allclose(np.asarray(res.values), ref_vals, atol=1e-4)


def test_pad_slots_never_certify():
    """k > num_live: the -inf/-1 pad slots must carry +inf gaps, not the
    NaN of (-inf) - (-inf)."""
    rng = np.random.default_rng(8)
    T = rng.standard_normal((4, 12)).astype(np.float32)
    U = rng.standard_normal((2, 12)).astype(np.float32)
    ctx = EngineContext(T, block_size=64)
    res = get_engine("norm").run(ctx, jnp.asarray(U), 7)
    gaps = np.asarray(certificate_gaps(res))
    ids = np.asarray(res.indices)
    assert not np.any(np.isnan(gaps))
    assert np.all(gaps[ids < 0] == np.inf)
    assert np.all(np.asarray(certified_counts(res)) == 4)


def test_budget_actually_caps_the_scan():
    """A tight budget must bound the scan depth (that is the whole
    admission-control point), and n_scored with it."""
    rng = np.random.default_rng(9)
    T = rng.standard_normal((2048, 12)).astype(np.float32)
    # anti-adversarial queries: orthogonal-ish, so full scans go deep
    U = rng.standard_normal((4, 12)).astype(np.float32)
    ctx = EngineContext(T, block_size=64, ta_chunk=16)
    for name in BUDGET_ENGINES:
        eng = get_engine(name)
        full = eng.run(ctx, jnp.asarray(U), K)
        capped = eng.run(ctx, jnp.asarray(U), K, budget=1)
        assert int(np.max(np.asarray(capped.depth))) <= \
            max(64, 16), (name, np.asarray(capped.depth))
        assert int(np.sum(np.asarray(capped.n_scored))) <= \
            int(np.sum(np.asarray(full.n_scored))), name


def test_certificate_gaps_requires_an_upper_bound():
    res = TopKResult(jnp.zeros((2, 3)), jnp.zeros((2, 3), jnp.int32),
                     jnp.zeros((2,), jnp.int32), jnp.zeros((2,), jnp.int32))
    with pytest.raises(ValueError, match="no upper bound"):
        certificate_gaps(res)


def test_budget_unsupported_engines_reject_loudly():
    """Engines that cannot halt early must refuse a budget instead of
    silently returning an uncertified-but-claimed-exact result."""
    rng = np.random.default_rng(10)
    T = rng.standard_normal((64, 12)).astype(np.float32)
    ctx = EngineContext(T, block_size=32)
    U = jnp.asarray(rng.standard_normal((2, 12)).astype(np.float32))
    with pytest.raises(ValueError, match="budget"):
        get_engine("norm_sharded").run(ctx, U, 3, budget=5)


def test_warmed_budgets_stay_compile_free_across_compaction():
    """The budget joins the executor config (DESIGN.md §10/§12): after
    warmup(budgets=...), budgeted queries before AND after a same-bucket
    compaction dispatch cached executables — zero process-wide retraces,
    engine_compiles_per_compaction == 0."""
    rng = np.random.default_rng(11)
    # R=15 keeps these signatures process-unique (the module-level
    # executors cache process-wide; see test_argpass.py)
    T = rng.standard_normal((200, 15)).astype(np.float32)
    srv = TopKServer(SepLRModel(jnp.asarray(T)), max_batch=8,
                     block_size=32, delta_capacity=16)
    srv.warmup(5, batch_sizes=(8,), engines=("norm", "bta"),
               budgets=(32,))
    U = rng.standard_normal((8, 15)).astype(np.float32)
    srv.query(U, 5, "norm", budget=32)
    srv.query(U, 5, "bta", budget=32)
    before = trace_totals()
    tails_before = dict(srv.catalogue.trace_counts)
    srv.add_targets(rng.standard_normal((16, 15)).astype(np.float32))
    srv.query(U, 5, "norm", budget=32)          # delta visible, budgeted
    srv.catalogue.compact(wait=True)            # same-bucket compaction
    srv.query(U, 5, "norm", budget=32)
    srv.query(U, 5, "bta", budget=32)
    assert trace_totals() == before
    assert srv.catalogue.trace_counts == tails_before
    assert srv.mutation_stats["engine_compiles_per_compaction"] == 0
    # and the budgeted result is still certificate-correct vs the oracle
    rows, _ = srv.catalogue.as_dense()
    ref_vals, _ = _dense_oracle(rows, U, 5)
    res = srv.query(U, 5, "norm", budget=32)
    gaps = np.asarray(res.upper)[:, None] - np.asarray(res.values)
    for q in range(U.shape[0]):
        c = int(np.sum(gaps[q] <= 0))
        np.testing.assert_allclose(np.asarray(res.values)[q, :c],
                                   ref_vals[q, :c], atol=1e-4)


def test_auto_with_budget_falls_back_to_a_budget_capable_engine():
    rng = np.random.default_rng(12)
    T = rng.standard_normal((300, 12)).astype(np.float32)
    ctx = EngineContext(T, block_size=64)
    U = jnp.asarray(rng.standard_normal((2, 12)).astype(np.float32))
    res = get_engine("auto").run(ctx, U, K, budget=8)
    assert res.upper is not None
    assert not np.any(np.isnan(np.asarray(certificate_gaps(res))))
