"""Async micro-batching pipeline: exactness, coalescing, the result
cache's invalidation contract, measured-cost dispatch, and the
zero-compile guarantee through the queue-formed path (DESIGN.md §13).

Shapes here are PROCESS-UNIQUE where a test asserts on trace counts:
the argument-passing executors are cached process-wide, so a shape
another test already traced would hide a retrace this test must see
(ROADMAP standing gotcha).
"""
import numpy as np
import pytest

from repro.core import CostTable, SepLRModel
from repro.core import faults
from repro.core.engines import (
    EngineContext,
    auto_candidates,
    batch_bucket,
    cost_label,
    get_engine,
    select_engine,
)
from repro.serving.pipeline import AsyncTopKServer, ResultCache
from repro.serving.server import AdmissionPolicy, TopKServer


def _model(m=512, r=16, seed=0):
    rng = np.random.default_rng(seed)
    T = rng.standard_normal((m, r)).astype(np.float32)
    U = rng.standard_normal((32, r)).astype(np.float32)
    return T, U


def _oracle_vals(T, U, k):
    s = U.astype(np.float64) @ T.astype(np.float64).T
    return np.sort(s, axis=1)[:, ::-1][:, :k]


# -- cost table (satellite: measured costs replace BATCHED_LIST_MIN_B) ----


def test_cost_table_fallback_chain():
    ct = CostTable()
    assert ct.predict("bta", 8, "mixed-dense") is None
    ct.observe("bta", 8, "mixed-dense", 1e-3)
    assert ct.predict("bta", 8, "mixed-dense") == pytest.approx(1e-3)
    # label miss -> empty-label entry -> engine aggregate
    ct.observe("bta", 16, "", 2e-3)
    assert ct.predict("bta", 16, "nonneg-dense") == pytest.approx(2e-3)
    assert ct.predict("bta", 4, "mixed-dense") is not None   # aggregate
    assert ct.predict("bta", 4, "mixed-dense",
                      granular_only=True) is None
    # EWMA folds, engine aggregate tracks every observation
    ct.observe("bta", 8, "mixed-dense", 2e-3)
    assert 1e-3 < ct.predict("bta", 8, "mixed-dense") < 2e-3
    assert ct.engine_cost("bta") is not None
    assert ct.engine_cost("never-ran") is None
    assert ct.n_observations == 3


def test_select_engine_measured_route_and_cold_fallback():
    T, _ = _model(m=521, r=18, seed=3)        # process-unique shape
    rng = np.random.default_rng(3)
    U = rng.standard_normal((8, 18)).astype(np.float32)
    ct = CostTable()
    ctx = EngineContext(T, cost_table=ct)
    cold = select_engine(ctx, U)              # heuristic (table empty)
    bucket = batch_bucket(U.shape[0])
    # measure every auto candidate; make one of them clearly cheapest
    cheap = ("ta" if cold.name != "ta" else "norm")
    for name in auto_candidates():
        lbl = cost_label(get_engine(name), ctx, U)
        ct.observe(name, bucket, lbl, 1e-9 if name == cheap else 1.0)
    assert select_engine(ctx, U).name == cheap
    # an UNMEASURED candidate at this bucket kills the measured route:
    # fresh table with partial coverage falls back to the heuristic
    ct2 = CostTable()
    ct2.observe(auto_candidates()[0], bucket,
                cost_label(get_engine(auto_candidates()[0]), ctx, U),
                1e-9)
    ctx2 = EngineContext(T, cost_table=ct2)
    assert select_engine(ctx2, U).name == cold.name
    # explicit-argument table overrides the context's
    assert select_engine(ctx2, U, cost_table=ct).name == cheap


def test_warmup_primes_cost_table_and_admission_uses_it():
    T, U = _model(m=517, r=20, seed=5)        # process-unique shape
    srv = TopKServer(SepLRModel(T), max_batch=8,
                     policy=AdmissionPolicy(deadline_ms=50.0))
    assert srv.cost_table.n_observations == 0
    srv.warmup(5, batch_sizes=(1, 8), engines=["bta", "norm"])
    # one timed run per warmed (engine, bucket, sign) landed in the table
    assert srv.cost_table.n_observations > 0
    assert srv.cost_table.engine_cost("bta") is not None
    # the ladder's fallback reads the warmed table when _cost_ewma is
    # empty: an engine measured as catastrophically slow is downgraded
    # on the FIRST query — "optimistic when unseen" no longer applies
    # to warmed engines
    for _ in range(64):                       # drown the EWMA in "slow"
        srv.cost_table.observe("bta", 8, "mixed-dense", 10.0)
        srv.cost_table.observe("bta", 8, "", 10.0)
    assert not srv._cost_ewma                 # nothing served yet
    res = srv.query(U[:8], 5, "bta")
    st = srv.stats["bta"]
    assert sum(st.degradations.values()) >= 1, st.degradations
    vals = _oracle_vals(T, U[:8], 5)
    assert np.allclose(np.asarray(res.values), vals, atol=1e-4)


# -- per-request latency accounting (satellite: honest p50/p99) -----------


def test_serve_stats_per_request_ring():
    T, U = _model()
    srv = TopKServer(SepLRModel(T), max_batch=8)
    srv.query(U[:4], 5, "norm")
    srv.query(U[:4], 5, "norm")
    st = srv.stats["norm"]
    assert len(st.lat_us_ring) == 1 or len(st.lat_us_ring) == 2
    # one per-request entry per query() CALL on the sync path
    assert len(st.req_lat_us_ring) == 2
    assert st.req_p50_us > 0 and st.req_p99_us >= st.req_p50_us
    empty = type(st)()
    assert empty.req_p99_us == 0.0


# -- the async pipeline ---------------------------------------------------


def test_async_exact_and_coalesces():
    T, U = _model(m=1024)
    srv = AsyncTopKServer(SepLRModel(T), max_batch=8, flush_ms=5.0,
                          method="bta")
    srv.warmup(5)
    with srv:
        res = srv.query(U, 5)                 # 32 one-row submissions
        assert np.allclose(np.asarray(res.values),
                           _oracle_vals(T, U, 5), atol=1e-4)
        ps = srv.pipeline_stats
        assert ps.n_requests == 32
        # the device-busy window coalesces: far fewer batches than
        # requests (first request dispatches alone on the idle pipeline)
        assert ps.n_batches < ps.n_requests
        assert max(int(b) for b in ps.batch_size_hist) > 1
        # per-REQUEST latency recorded for every submission; per-batch
        # ring only for dispatched batches
        st = srv.stats["bta"]
        assert len(st.req_lat_us_ring) == 32
        assert len(st.lat_us_ring) == ps.n_batches
    # close() is idempotent and the threads are down
    srv.close()
    with pytest.raises(RuntimeError):
        srv.submit(U[0], 5)


def test_async_submit_validation():
    T, _ = _model()
    srv = AsyncTopKServer(SepLRModel(T), max_batch=4)
    with srv:
        with pytest.raises(ValueError):
            srv.submit(np.ones(16, np.float32), 0)
        with pytest.raises(ValueError):
            srv.submit(np.ones(7, np.float32), 5)      # wrong rank
        with pytest.raises(ValueError):
            srv.submit(np.full(16, np.nan, np.float32), 5)
        with pytest.raises(ValueError):
            srv.submit(np.ones(16, np.float32), 5, deadline_ms=-1.0)


def test_async_deadline_shed_at_dispatch():
    T, U = _model()
    srv = AsyncTopKServer(
        SepLRModel(T), max_batch=4, method="bta",
        policy=AdmissionPolicy(deadline_ms=0.0))
    srv.warmup(5)
    with srv:
        res = srv.submit(U[0], 5).result(timeout=30)
        # the PR-7 sentinel, via the queue: nothing certified, nothing
        # pretending to be a result
        assert np.all(np.asarray(res.indices) == -1)
        assert np.all(np.isneginf(np.asarray(res.values)))
        assert np.all(np.isposinf(np.asarray(res.upper)))
        st = srv.stats["bta"]
        assert st.degradations.get("shed", 0) >= 1
        assert srv.pipeline_stats.n_shed >= 1


# -- result cache ---------------------------------------------------------


def test_result_cache_lru_and_counters():
    c = ResultCache(capacity=2)
    c.insert(("a", 5, (0, 0)), ("ra",))
    c.insert(("b", 5, (0, 0)), ("rb",))
    assert c.lookup(("a", 5, (0, 0))) == ("ra",)      # refreshes "a"
    c.insert(("c", 5, (0, 0)), ("rc",))               # evicts "b"
    assert c.lookup(("b", 5, (0, 0))) is None
    assert c.lookup(("a", 5, (0, 0))) == ("ra",)
    assert c.hits == 2 and c.misses == 1 and len(c) == 2
    c.invalidate()
    assert len(c) == 0 and c.n_invalidations == 1


def test_async_cache_hits_and_mutation_invalidation():
    T, U = _model(m=1024)
    srv = AsyncTopKServer(SepLRModel(T), max_batch=8, method="bta",
                          delta_capacity=16)
    srv.warmup(5)
    rank = T.shape[1]
    with srv:
        u = U[0]
        r1 = srv.submit(u, 5).result(timeout=30)
        misses0 = srv.cache.misses
        r2 = srv.submit(u, 5).result(timeout=30)
        assert srv.cache.hits >= 1
        assert srv.cache.misses == misses0    # second ask never scanned
        assert np.array_equal(np.asarray(r1.values),
                              np.asarray(r2.values))
        # ADD: a row that must be the new top-1 — the cached answer is
        # stale the instant the append lands
        big = 100.0 * u / max(float(np.linalg.norm(u)), 1e-9)
        gid = int(srv.add_targets(big[None])[0])
        r3 = srv.submit(u, 5).result(timeout=30)
        assert int(np.asarray(r3.indices)[0, 0]) == gid
        # DELETE: and it disappears again, exactly
        srv.delete_targets([gid])
        r4 = srv.submit(u, 5).result(timeout=30)
        assert gid not in set(np.asarray(r4.indices)[0].tolist())
        assert np.allclose(np.asarray(r4.values)[0],
                           _oracle_vals(T, u[None], 5)[0], atol=1e-4)
        # UPDATE through the delegating wrapper keeps exactness too
        gid2 = int(srv.add_targets(big[None])[0])
        srv.update_targets([gid2], -big[None])
        r5 = srv.submit(u, 5).result(timeout=30)
        assert int(np.asarray(r5.indices)[0, 0]) != gid2


def test_async_cache_never_serves_across_version_bump():
    T, U = _model(m=1024)
    srv = AsyncTopKServer(SepLRModel(T), max_batch=8, method="bta",
                          delta_capacity=16)
    srv.warmup(5)
    with srv:
        u = U[1]
        srv.submit(u, 5).result(timeout=30)
        assert len(srv.cache) == 1
        v0 = srv.catalogue.version
        rows = np.random.default_rng(9).standard_normal(
            (1, T.shape[1])).astype(np.float32)
        srv.add_targets(rows)
        srv.catalogue.compact(wait=True)      # version bump
        assert srv.catalogue.version > v0
        # the compaction-fired listener emptied the cache, and the next
        # ask re-scans (a miss, not a hit) under the NEW token
        assert len(srv.cache) == 0
        hits0 = srv.cache.hits
        res = srv.submit(u, 5).result(timeout=30)
        assert srv.cache.hits == hits0
        live = np.concatenate([T, rows])
        assert np.allclose(np.asarray(res.values)[0],
                           _oracle_vals(live, u[None], 5)[0], atol=1e-4)


def test_async_cache_safe_under_failed_build():
    """A fault-injected FAILED compaction build must not let the cache
    serve pre-mutation answers: the mutation epoch bumped regardless,
    and the chain keeps serving exact results."""
    T, U = _model(m=1024)
    srv = AsyncTopKServer(SepLRModel(T), max_batch=8, method="bta",
                          delta_capacity=4)
    srv.warmup(5)
    rank = T.shape[1]
    with srv:
        u = U[2]
        srv.submit(u, 5).result(timeout=30)   # prime the cache
        big = 50.0 * u / max(float(np.linalg.norm(u)), 1e-9)
        with faults.injected("compaction.build",
                             error=faults.FaultInjected):
            # enough appends to overflow the delta and trigger the
            # (failing) build — the sealed chain keeps serving
            gids = [int(srv.add_targets(big[None])[0])]
            for i in range(6):
                gids.append(int(srv.add_targets(
                    0.01 * np.ones((1, rank), np.float32))[0]))
            assert srv.catalogue.stats.n_failed_compactions >= 1
            res = srv.submit(u, 5).result(timeout=30)
            assert int(np.asarray(res.indices)[0, 0]) == gids[0]
        # recovery: a forced compact folds the chain; still exact
        srv.catalogue.compact(wait=True)
        res2 = srv.submit(u, 5).result(timeout=30)
        assert int(np.asarray(res2.indices)[0, 0]) == gids[0]


# -- the zero-compile guarantee through the async path --------------------


def test_async_compaction_compile_free():
    """Queue-formed micro-batches only ever dispatch warmed (bucket,
    sign, engine) configs: compactions under async traffic retrace
    NOTHING (the acceptance-pinned invariant). Process-unique shape."""
    rng = np.random.default_rng(11)
    T = rng.standard_normal((613, 22)).astype(np.float32)
    U = rng.standard_normal((64, 22)).astype(np.float32)
    srv = AsyncTopKServer(SepLRModel(T), max_batch=8, method="auto",
                          delta_capacity=8)
    srv.warmup(6)
    with srv:
        srv.query(U[:16], 6)                  # traffic before mutations
        for i in range(20):                   # forces >= 2 compactions
            srv.add_targets(rng.standard_normal(
                (1, 22)).astype(np.float32))
            if i % 5 == 0:
                srv.query(U[16 + i:17 + i], 6)
        srv.catalogue.flush()
        srv.query(U[:32], 6)                  # post-compaction traffic
        ms = srv.mutation_stats
        assert ms["n_compactions"] >= 1
        assert ms["engine_compiles_per_compaction"] == 0, ms
        # and the traffic stayed exact throughout — the oracle check on
        # the final state (catalogue = T + 20 appended rows)
    live, gids = srv.catalogue.as_dense()
    res = srv.server.query(U[:4], 6, "norm")
    assert np.allclose(np.asarray(res.values),
                       _oracle_vals(live, U[:4], 6), atol=1e-4)
