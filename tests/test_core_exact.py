"""Exactness and paper-theorem tests for the top-K core (deterministic).

Property-based (hypothesis) variants live in ``test_core_properties.py``
and are skipped automatically when hypothesis is not installed; everything
here runs with numpy-seeded determinism only.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    blocked_topk,
    blocked_topk_batched,
    fagin_topk_np,
    naive_topk,
    norm_pruned_topk,
    partial_threshold_topk_np,
    threshold_topk_from_index,
    threshold_topk_np,
)
from repro.core.index import build_index
from repro.core.toy import TOY_BEST_ITEM, TOY_SCORES, TOY_T, TOY_U, table2_adversarial


def _problem(seed, sparse=False, negate=False):
    rng = np.random.default_rng(seed)
    m = int(rng.integers(5, 120))
    r = int(rng.integers(2, 16))
    k = int(rng.integers(1, min(m, 8) + 1))
    T = rng.standard_normal((m, r)).astype(np.float32)
    u = rng.standard_normal(r).astype(np.float32)
    if sparse:
        u[rng.random(r) < 0.5] = 0.0
        if np.all(u == 0):
            u[0] = 1.0
    if negate:
        u = -np.abs(u)
    return T, u, k


PROBLEMS = ([(s, False, False) for s in range(8)]
            + [(s, True, False) for s in range(8, 14)]
            + [(s, False, True) for s in range(14, 20)])


# ---------------------------------------------------------------------------
# Paper worked examples
# ---------------------------------------------------------------------------


class TestPaperExamples:
    def test_toy_scores_match_paper(self):
        expected = [-4.85, -4.71, -0.73, -5.37, 0.93, 4.7, -0.59, 1.46,
                    1.49, 2.6]
        np.testing.assert_allclose(TOY_SCORES, expected, atol=1e-5)

    def test_toy_threshold_algorithm(self):
        idx = build_index(TOY_T)
        vals, ids, stats = threshold_topk_np(
            TOY_T, np.asarray(idx.order_desc), TOY_U, 1)
        assert ids[0] == TOY_BEST_ITEM
        assert stats.n_scored == 5          # paper: five of ten scored
        assert stats.depth == 2             # paper: terminates in 2 rounds

    def test_toy_fagin(self):
        idx = build_index(TOY_T)
        vals, ids, stats = fagin_topk_np(
            TOY_T, np.asarray(idx.order_desc), TOY_U, 1)
        assert ids[0] == TOY_BEST_ITEM
        assert stats.n_scored == 9          # paper: nine of ten scored
        assert stats.depth == 5             # paper: stops at depth five

    def test_fagin_not_instance_optimal(self):
        """Theorem 3 via the Table 2 construction: TA depth 2, FA ~M/2."""
        T, u = table2_adversarial(400)
        idx = build_index(T)
        order = np.asarray(idx.order_desc)
        _, _, s_ta = threshold_topk_np(T, order, u, 1)
        _, _, s_fa = fagin_topk_np(T, order, u, 1)
        assert s_ta.depth == 2
        assert s_fa.depth >= 180            # ~M/2

    def test_jax_ta_counts_match_oracle_on_toy(self):
        idx = build_index(TOY_T)
        res = threshold_topk_from_index(
            jnp.asarray(TOY_T), idx, jnp.asarray(TOY_U), 1)
        assert int(res.indices[0]) == TOY_BEST_ITEM
        assert int(res.n_scored) == 5 and int(res.depth) == 2


# ---------------------------------------------------------------------------
# Deterministic exactness sweeps (random / sparse / negative queries)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed,sparse,negate", PROBLEMS)
def test_ta_equals_naive(seed, sparse, negate):
    T, u, k = _problem(seed, sparse, negate)
    nv = np.sort(np.asarray(naive_topk(jnp.asarray(T), jnp.asarray(u), k).values))
    idx = build_index(T)
    tv, _, ts = threshold_topk_np(T, np.asarray(idx.order_desc), u, k)
    np.testing.assert_allclose(np.sort(tv), nv, atol=1e-4)
    jr = threshold_topk_from_index(jnp.asarray(T), idx, jnp.asarray(u), k)
    np.testing.assert_allclose(np.sort(np.asarray(jr.values)), nv, atol=1e-4)
    # the JAX TA is count-faithful to the oracle
    assert int(jr.n_scored) == ts.n_scored
    assert int(jr.depth) == ts.depth


@pytest.mark.parametrize("seed,sparse,negate", PROBLEMS[::2])
@pytest.mark.parametrize("block", [1, 3, 8, 32])
def test_bta_exact_any_block_size(seed, sparse, negate, block):
    T, u, k = _problem(seed, sparse, negate)
    nv = np.sort(np.asarray(naive_topk(jnp.asarray(T), jnp.asarray(u), k).values))
    idx = build_index(T)
    r = blocked_topk(jnp.asarray(T), idx.order_desc, idx.t_sorted_desc,
                     jnp.asarray(u), k, block_size=block)
    np.testing.assert_allclose(np.sort(np.asarray(r.values)), nv, atol=1e-4)


@pytest.mark.parametrize("seed,sparse,negate", PROBLEMS[::2])
def test_norm_pruned_exact(seed, sparse, negate):
    T, u, k = _problem(seed, sparse, negate)
    nv = np.sort(np.asarray(naive_topk(jnp.asarray(T), jnp.asarray(u), k).values))
    idx = build_index(T)
    r = norm_pruned_topk(jnp.asarray(T), idx.norm_order, idx.norms_sorted,
                         jnp.asarray(u), k, block_size=16)
    np.testing.assert_allclose(np.sort(np.asarray(r.values)), nv, atol=1e-4)


@pytest.mark.parametrize("seed", range(5))
def test_partial_ta_same_set_fewer_mults(seed):
    T, u, k = _problem(seed)
    idx = build_index(T)
    order = np.asarray(idx.order_desc)
    tv, _, ts = threshold_topk_np(T, order, u, k)
    pv, _, ps = partial_threshold_topk_np(T, order, u, k)
    np.testing.assert_allclose(np.sort(pv), np.sort(tv), atol=1e-5)
    # Alg. 3 touches the same items and never computes MORE than R terms each
    assert ps.n_items_touched == ts.n_scored
    assert ps.avg_score_fraction <= 1.0 + 1e-9


@pytest.mark.parametrize("seed", range(5))
def test_theorem4_ta_never_scores_more_than_fagin(seed):
    T, u, k = _problem(seed)
    idx = build_index(T)
    order = np.asarray(idx.order_desc)
    _, _, ts = threshold_topk_np(T, order, u, k)
    _, _, fs = fagin_topk_np(T, order, u, k)
    assert ts.n_scored <= fs.n_scored


@pytest.mark.parametrize("seed", range(5))
def test_bounds_invariants(seed):
    """LB is monotone; the loop runs iff LB < UB; the final LB is the true
    K-th best (the exactness certificate the UB trajectory must deliver)."""
    T, u, k = _problem(seed)
    idx = build_index(T)
    _, _, ts = threshold_topk_np(T, np.asarray(idx.order_desc), u, k,
                                 track_trajectory=True)
    lbs, ubs = ts.lower_bounds, ts.upper_bounds
    assert np.all(np.diff(lbs[np.isfinite(lbs)]) >= -1e-6)
    # every non-final round must have had lb < ub, else TA would have stopped
    assert np.all(lbs[:-1] < ubs[:-1] + 1e-6)
    # termination: certificate closed or lists exhausted
    assert lbs[-1] >= ubs[-1] - 1e-6 or ts.depth == T.shape[0]
    kth_best = np.sort(T @ u)[::-1][k - 1]
    np.testing.assert_allclose(lbs[-1], kth_best, atol=1e-5)


def test_batched_bta_matches_single():
    rng = np.random.default_rng(3)
    T = rng.standard_normal((300, 12)).astype(np.float32)
    U = rng.standard_normal((7, 12)).astype(np.float32)
    idx = build_index(T)
    batched = blocked_topk_batched(jnp.asarray(T), idx, jnp.asarray(U), 5,
                                   block_size=16)
    for i, u in enumerate(U):
        single = blocked_topk(jnp.asarray(T), idx.order_desc,
                              idx.t_sorted_desc, jnp.asarray(u), 5,
                              block_size=16)
        np.testing.assert_allclose(np.asarray(batched.values[i]),
                                   np.asarray(single.values), atol=1e-5)
        # liveness gating: lockstep batching must not inflate the stats of
        # queries that certified early
        assert int(batched.n_scored[i]) == int(single.n_scored)
        assert int(batched.depth[i]) == int(single.depth)


def test_halted_ta_budget_respected():
    rng = np.random.default_rng(4)
    T = rng.standard_normal((500, 20)).astype(np.float32)
    u = rng.standard_normal(20).astype(np.float32)
    idx = build_index(T)
    r = threshold_topk_from_index(jnp.asarray(T), idx, jnp.asarray(u), 5,
                                  max_rounds=3)
    assert int(r.depth) <= 3
    # halted results are a subset of scored items - values are real scores
    scores = T @ u
    ids = np.asarray(r.indices)
    ids = ids[ids >= 0]
    np.testing.assert_allclose(np.asarray(r.values)[: len(ids)], scores[ids],
                               atol=1e-4)


def test_halted_norm_pruned_budget_respected():
    """max_blocks is the uniform halting knob across every strategy."""
    rng = np.random.default_rng(5)
    T = rng.standard_normal((500, 20)).astype(np.float32)
    u = rng.standard_normal(20).astype(np.float32)
    idx = build_index(T)
    r = norm_pruned_topk(jnp.asarray(T), idx.norm_order, idx.norms_sorted,
                         jnp.asarray(u), 5, block_size=32, max_blocks=2)
    assert int(r.depth) <= 2 * 32
    scores = T @ u
    ids = np.asarray(r.indices)
    ids = ids[ids >= 0]
    np.testing.assert_allclose(np.asarray(r.values)[: len(ids)], scores[ids],
                               atol=1e-4)
