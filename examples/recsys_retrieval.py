"""Two-stage recsys serving (DESIGN.md §3): train DeepFM on synthetic
clicks, then serve retrieval through the EXACT SEP-LR top-K engine and
re-rank the retrieved candidates with the full (non-separable) model.

    PYTHONPATH=src python examples/recsys_retrieval.py
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import from_matrix_factorization
from repro.data.loader import PrefetchLoader
from repro.data.synthetic import recsys_batches
from repro.models import recsys as recsys_mod
from repro.serving.server import TopKServer, TwoStageRanker
from repro.train.optimizer import OptimizerConfig
from repro.train.trainer import Trainer, TrainerConfig

CFG = recsys_mod.RecsysConfig("deepfm-demo", "deepfm", n_dense=0,
                              n_sparse=12, embed_dim=16,
                              vocab_per_field=2000, mlp_dims=(64, 64))
N_CANDIDATES = 20_000


def main():
    # 1) train the ranking model on synthetic click logs
    params = recsys_mod.init_params(CFG, jax.random.PRNGKey(0))
    opt = OptimizerConfig(kind="adamw", lr=3e-3, warmup_steps=10,
                          total_steps=150)
    data = PrefetchLoader(lambda: recsys_batches(
        0, CFG.n_dense, CFG.n_sparse, CFG.vocab_per_field, 256))
    tr = Trainer(lambda p, b: recsys_mod.loss_fn(p, b, CFG), params, opt,
                 data, TrainerConfig(total_steps=150, log_every=25))
    final = tr.run()
    print(f"DeepFM trained: loss {tr.history[0]['loss']:.4f} -> "
          f"{final['loss']:.4f} (acc {final['acc']:.2%})")

    # 2) candidate catalogue = item-tower embeddings (SEP-LR by design)
    rng = np.random.default_rng(1)
    candidates = jnp.asarray(
        rng.standard_normal((N_CANDIDATES, CFG.embed_dim)).astype(np.float32)
        * (1.0 / np.sqrt(1.0 + rng.random(N_CANDIDATES)))[:, None])
    retrieval = TopKServer(from_matrix_factorization(candidates, "items"),
                           max_batch=16, block_size=256)

    # 3) two-stage: exact top-100 retrieval -> full-model re-rank
    def rerank(query_batch, cand_ids):
        # full DeepFM forward on (query, candidate) pairs: inject the
        # candidate id into the last sparse field
        B, N = cand_ids.shape
        scores = np.zeros((B, N), np.float32)
        for b in range(B):
            sp = np.repeat(query_batch["sparse"][b][None], N, axis=0).copy()
            sp[:, -1] = cand_ids[b] % CFG.vocab_per_field
            logits = recsys_mod.forward(
                tr.params, {"dense": jnp.zeros((N, 0)),
                            "sparse": jnp.asarray(sp)}, CFG)
            scores[b] = np.asarray(logits)
        return scores

    ranker = TwoStageRanker(retrieval, rerank, retrieve_n=100)
    queries = next(iter(PrefetchLoader(lambda: recsys_batches(
        7, CFG.n_dense, CFG.n_sparse, CFG.vocab_per_field, 4))))
    U = recsys_mod.query_tower(tr.params, {
        "dense": jnp.asarray(queries["dense"]),
        "sparse": jnp.asarray(queries["sparse"])}, CFG)
    ids, scores = ranker.rank(queries, U, k=5, method="bta")
    st = retrieval.stats["bta"]
    print(f"retrieved top-100 of {N_CANDIDATES} exactly with "
          f"{st.scores_per_query:.0f} scores/query "
          f"({st.scores_per_query / N_CANDIDATES:.1%} of naive), "
          f"then re-ranked to top-5:")
    for b in range(4):
        print(f"  query {b}: items {ids[b].tolist()}")


if __name__ == "__main__":
    main()
