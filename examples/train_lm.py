"""End-to-end driver: train a ~100M-parameter dense LM for a few hundred
steps on synthetic data with the full substrate (AdamW, checkpointing,
fault-tolerant trainer), then serve a few decode steps through the SEP-LR
top-K head.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--tiny]

``--tiny`` (CI mode) shrinks the model so the example finishes in ~1 min
on this 1-core CPU container; the default ~100M config is the honest
"train a real model" path and takes a few hours of CPU.
"""

import argparse
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.loader import PrefetchLoader
from repro.data.synthetic import lm_batches
from repro.models import transformer as tf_mod
from repro.train.optimizer import OptimizerConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    if args.tiny:
        cfg = tf_mod.TransformerConfig(
            name="lm-tiny", n_layers=2, d_model=128, n_heads=4,
            n_kv_heads=2, head_dim=32, d_ff=256, vocab_size=2048,
            logit_chunk=64, kv_block=64)
    else:
        # ~100M params: 12L x 768d (GPT-2-small-ish), GQA 12/4
        cfg = tf_mod.TransformerConfig(
            name="lm-100m", n_layers=12, d_model=768, n_heads=12,
            n_kv_heads=4, head_dim=64, d_ff=3072, vocab_size=32768,
            logit_chunk=128, kv_block=128)
    params = tf_mod.init_params(cfg, jax.random.PRNGKey(0))
    n_params = cfg.param_count()
    print(f"model: {cfg.name}, {n_params/1e6:.1f}M params")

    ckpt_dir = args.ckpt_dir or os.path.join(tempfile.gettempdir(),
                                             f"ckpt_{cfg.name}")
    opt = OptimizerConfig(kind="adamw", lr=3e-3 if args.tiny else 6e-4,
                          warmup_steps=max(args.steps // 20, 5),
                          total_steps=args.steps)
    data = PrefetchLoader(lambda: lm_batches(
        0, cfg.vocab_size, args.batch, args.seq_len))
    tr = Trainer(lambda p, b: tf_mod.loss_fn(p, b, cfg), params, opt, data,
                 TrainerConfig(total_steps=args.steps, log_every=10,
                               ckpt_every=max(args.steps // 4, 10),
                               ckpt_dir=ckpt_dir))
    final = tr.run()
    print(f"trained {tr.step} steps; loss "
          f"{tr.history[0]['loss']:.4f} -> {final['loss']:.4f}; "
          f"checkpoints in {ckpt_dir}")

    # --- decode through the exact top-K head (the paper's technique) -----
    cache = tf_mod.init_kv_cache(cfg, 1, 32)
    tok = jnp.asarray([[1]], jnp.int32)
    for t in range(8):
        (vals, idx), cache = tf_mod.serve_step(tr.params, cache, tok, t,
                                               cfg, top_k=8)
        tok = idx[:, :1]   # greedy decode from the exact top-K set
    print("decoded 8 tokens via the SEP-LR top-K head; last top-8 ids:",
          np.asarray(idx[0]))


if __name__ == "__main__":
    main()
