"""Observability walkthrough: traces, metrics and the event journal
under mixed traffic (DESIGN.md §14).

Runs an ``AsyncTopKServer`` through query + mutation + fault-injected
traffic, then prints the three views the obs layer provides:

1. the SPAN TREE of one slow request — queue wait, coalescing, the
   cost-table routing decision, device time, and the (snapshot version,
   mutation epoch) the scan executed against;
2. a Prometheus dump of the metrics registry (what a scraper would
   collect from this process);
3. the tail of the event journal — compactions, epoch bumps, fault
   firings and cache invalidations, carrying the same version/epoch
   join keys the spans do.

    PYTHONPATH=src python examples/observability.py
"""

import numpy as np

from repro import obs
from repro.core import SepLRModel, faults
from repro.serving.pipeline import AsyncTopKServer

rng = np.random.default_rng(0)
M, R, K = 5_000, 16, 5

obs.reset()
obs.TRACER.sample_rate = 1.0          # demo: trace everything

model = SepLRModel(rng.standard_normal((M, R)).astype(np.float32))
with AsyncTopKServer(model, max_batch=16, delta_capacity=32,
                     method="bta") as srv:
    srv.warmup(K)
    obs.reset()                        # drop warmup noise from the story

    # -- mixed traffic: queries interleaved with mutations ------------------
    print(f"catalogue: M={M} R={R}; querying while mutating "
          f"(delta_capacity=32 → appends force compactions)")
    for round_ in range(3):
        handles = [srv.submit(rng.standard_normal(R).astype(np.float32),
                              K) for _ in range(24)]
        for h in handles:
            h.result(timeout=60)
        gids = srv.add_targets(
            rng.standard_normal((20, R)).astype(np.float32))
        srv.delete_targets(gids[:5])
    # a budgeted (certificate-carrying) request and a repeated one (the
    # second hit comes straight from the result cache)
    u = rng.standard_normal(R).astype(np.float32)
    srv.submit(u, K).result(timeout=60)
    srv.submit(u, K).result(timeout=60)
    srv.submit(u, K, method="norm", budget=200).result(timeout=60)

    # -- a fault: the next compaction build fails once, then recovers -------
    with faults.injected("compaction.build", error=faults.FaultInjected,
                         times=1):
        try:
            srv.add_targets(
                rng.standard_normal((40, R)).astype(np.float32))
        except faults.FaultInjected:
            pass                       # sync compaction surfaces the fault
    for _ in range(8):                 # queries keep serving through it
        srv.submit(rng.standard_normal(R).astype(np.float32),
                   K).result(timeout=60)

    # -- view 1: the slowest request's span tree ----------------------------
    print("\n=== slowest request (span tree) ===")
    trace = obs.TRACER.slowest()
    print(trace.format_tree())

    # -- view 2: the Prometheus exposition ----------------------------------
    print("\n=== metrics (Prometheus exposition, excerpt) ===")
    prom = obs.REGISTRY.render_prom()
    wanted = ("repro_queries_total", "repro_scored_fraction_count",
              "repro_cache_lookups_total", "repro_compaction_events",
              "repro_faults_fired", "repro_epoch_bumps",
              "repro_request_latency_us_count", "repro_cost_table_us")
    for line in prom.splitlines():
        if line.startswith(wanted):
            print(line)
    n_samples = len(obs.parse_prom_text(prom))
    print(f"... ({n_samples} samples total; "
          f"obs.REGISTRY.render_prom() for the full exposition)")

    # -- view 3: the event journal tail -------------------------------------
    print("\n=== event journal (last 15) ===")
    for ev in obs.JOURNAL.tail(15):
        print(ev)

    # the join: spans carry (version, epoch); so do compaction events
    dev = trace.find("device")
    if dev is not None and "version" in dev.attrs:
        v = dev.attrs["version"]
        produced = obs.JOURNAL.events("compaction.success", version=v)
        print(f"\nslowest request ran against snapshot version {v}; "
              f"journal records {len(produced)} compaction.success "
              f"event(s) producing that version")

    obs.validate_snapshot(obs.REGISTRY.snapshot())
    print("\nmetrics snapshot validates against the checked-in schema; "
          "span store holds "
          f"{len(obs.TRACER.traces())} traces (bounded at 256)")
