"""Quickstart: build a SEP-LR model, index it, and query exact top-K
through every engine — the paper's core loop in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core import (
    blocked_topk,
    build_index,
    naive_topk,
    random_model,
    threshold_topk_from_index,
)

# 1) A trained SEP-LR model is just a catalogue of target factors t(y).
#    (Any matrix-factorisation / multi-label / dyadic model reduces to this
#    — see repro.core.seplr adapters.)
rng = np.random.default_rng(0)
model = random_model(rng, num_targets=50_000, rank=30,
                     distribution="lowrank_spectrum")
print(f"catalogue: M={model.num_targets} items, R={model.rank}")

# 2) Build the sorted-list index once, offline (O(R M log M)).
index = build_index(model.targets)

# 3) Query. The naive baseline scores all M items...
u = jnp.asarray(rng.standard_normal(model.rank).astype(np.float32)
                * (1.0 / np.sqrt(1.0 + np.arange(model.rank))))
naive = naive_topk(model.targets, u, k=10)
print(f"naive     : top-1 score {float(naive.values[0]):.4f}, "
      f"{int(naive.n_scored):>6d} scores computed")

# ...the Threshold Algorithm proves the same top-10 after far fewer scores...
ta = threshold_topk_from_index(model.targets, index, u, k=10)
print(f"TA        : top-1 score {float(ta.values[0]):.4f}, "
      f"{int(ta.n_scored):>6d} scores computed "
      f"({int(ta.n_scored) / model.num_targets:.1%} of naive)")

# ...and the Block Threshold Algorithm does it in MXU-shaped block work.
bta = blocked_topk(model.targets, index.order_desc, index.t_sorted_desc,
                   u, k=10, block_size=256)
print(f"BTA(b=256): top-1 score {float(bta.values[0]):.4f}, "
      f"{int(bta.n_scored):>6d} scores computed, "
      f"{int(bta.depth) // 256} blocks")

assert np.allclose(np.sort(np.asarray(naive.values)),
                   np.sort(np.asarray(ta.values)), atol=1e-4)
assert np.allclose(np.sort(np.asarray(naive.values)),
                   np.sort(np.asarray(bta.values)), atol=1e-4)
print("all three engines returned the identical exact top-10.")
