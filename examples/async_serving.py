"""Async micro-batching demo: independent requests, batched scans.

Boots an ``AsyncTopKServer``, fires a burst of single-query submissions
from many client threads, and prints what the pipeline did with them:
how the queue coalesced arrivals into power-of-two buckets, the honest
per-request latency percentiles (enqueue→result, queue wait included),
the result cache earning hits on repeated head queries, and a mutation
invalidating those hits mid-traffic — every answer exact throughout
(DESIGN.md §13).

    PYTHONPATH=src python examples/async_serving.py
"""

import threading
import time

import numpy as np

from repro.core import SepLRModel
from repro.serving.pipeline import AsyncTopKServer

rng = np.random.default_rng(0)
M, R, K = 20_000, 24, 10

# 1) Boot and warm. The async warmup covers EVERY power-of-two bucket
#    up to max_batch — traffic decides the coalesced size, so every
#    size it can produce must hit a compiled executable.
T = rng.standard_normal((M, R)).astype(np.float32)
srv = AsyncTopKServer(SepLRModel(T), max_batch=16, flush_ms=2.0)
srv.warmup(K)
print(f"catalogue: M={M} items, R={R}; method='auto', K={K}")

queries = rng.standard_normal((256, R)).astype(np.float32)
oracle = np.sort(queries.astype(np.float64) @ T.astype(np.float64).T,
                 axis=1)[:, ::-1][:, :K]

with srv:
    # 2) A burst of independent clients, one query each — the serving
    #    shape the paper's "scalable" claim actually meets in the wild.
    n_bad = 0

    def client(lo, hi):
        global n_bad
        for i in range(lo, hi):
            res = srv.submit(queries[i], K).result()
            if not np.allclose(np.asarray(res.values)[0], oracle[i],
                               atol=1e-3):
                n_bad += 1

    t0 = time.perf_counter()
    threads = [threading.Thread(target=client, args=(j * 32, (j + 1) * 32))
               for j in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    dt = time.perf_counter() - t0
    ps = srv.pipeline_stats
    st = srv.stats["auto"]
    print(f"burst: 256 requests in {dt * 1e3:.0f}ms "
          f"({256 / dt:.0f} qps), {n_bad} wrong")
    print(f"coalescing: {ps.n_batches} micro-batches, "
          f"mean size {ps.mean_batch_size:.1f}, "
          f"histogram {ps.batch_size_hist}")
    print(f"per-request latency: p50={st.req_p50_us / 1e3:.2f}ms "
          f"p99={st.req_p99_us / 1e3:.2f}ms")

    # 3) Head queries repeat: the result cache answers without a scan —
    #    until a mutation lands, which invalidates it (the cache token
    #    carries the catalogue's version AND mutation epoch).
    hot = queries[0]
    for _ in range(5):
        srv.submit(hot, K).result()
    print(f"cache: {srv.cache.hits} hits / {srv.cache.misses} misses")

    big = 100.0 * hot / np.linalg.norm(hot)
    gid = int(srv.add_targets(big[None])[0])
    res = srv.submit(hot, K).result()
    assert int(np.asarray(res.indices)[0, 0]) == gid, "stale cache!"
    print(f"mutation: appended gid {gid} is instantly top-1 "
          f"(cache invalidated, re-scanned exactly)")

print("done — all results exact" if n_bad == 0 else "FAILED")
