"""Streaming catalogue demo: exact top-K while the catalogue mutates.

Boots a ``TopKServer``, streams item inserts / updates / deletes while
querying, and prints exactness + delta/compaction stats after every
round — the paper's exactness guarantee surviving a mutating catalogue
(DESIGN.md §9: base snapshot + delta segment + tombstones, folded by a
threshold-triggered compaction).

    PYTHONPATH=src python examples/streaming_catalog.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core import SepLRModel
from repro.serving.server import TopKServer

rng = np.random.default_rng(0)
M, R, K = 20_000, 24, 10

# 1) Boot a server over the initial catalogue and warm it: engines AND the
#    streaming layer's delta buckets compile ahead of traffic, so the first
#    query after any insert dispatches cached executables (0 retraces).
model = SepLRModel(jnp.asarray(
    rng.standard_normal((M, R)).astype(np.float32)
    * (1.0 / np.sqrt(1.0 + np.arange(M, dtype=np.float32)))[:, None]))
srv = TopKServer(model, max_batch=8, delta_capacity=64)
srv.warmup(K, batch_sizes=(8,), engines=["norm"])
print(f"catalogue: M={M} items, R={R}; serving method='norm', K={K}")

def exact_against_rebuild(U, res):
    """Oracle: dense top-K over a fresh dump of every live item."""
    rows, gids = srv.catalogue.as_dense()
    scores = U @ rows.T
    best = np.sort(scores, axis=1)[:, -K:][:, ::-1]
    return bool(np.allclose(np.sort(res.values, axis=1)[:, ::-1],
                            best, atol=1e-4))

live = list(range(M))
for rnd in range(6):
    # 2) Mutate: new items arrive, stale ones leave, a few get re-embedded.
    new_gids = srv.add_targets(
        rng.standard_normal((24, R)).astype(np.float32))
    live.extend(int(g) for g in new_gids)
    victims = [live.pop(int(rng.integers(len(live)))) for _ in range(8)]
    srv.delete_targets(victims)
    upd = [live[int(rng.integers(len(live)))] for _ in range(8)]
    srv.update_targets(upd, rng.standard_normal((8, R)).astype(np.float32))

    # 3) Query mid-stream: results carry GLOBAL ids and stay provably
    #    exact at any delta occupancy / tombstone count.
    U = rng.standard_normal((8, R)).astype(np.float32)
    res = srv.query(U, K, "norm")
    ms = srv.mutation_stats
    print(f"round {rnd}: exact={exact_against_rebuild(U, res)} "
          f"live={ms['num_live']} delta={ms['delta_occupancy']}"
          f"/{srv.catalogue.delta_capacity} "
          f"tombstones={ms['n_tombstones']} "
          f"compactions={ms['n_compactions']} "
          f"(snapshot v{ms['snapshot_version']})")

st = srv.stats["norm"]
print(f"served {st.n_queries} queries: {st.scores_per_query:.0f} scores/q "
      f"(of {ms['num_live']} live), p50={st.p50_us:.0f}us "
      f"p95={st.p95_us:.0f}us p99={st.p99_us:.0f}us")
ms = srv.mutation_stats
assert ms["n_compactions"] >= 1, "stream never compacted"
# 4) Compaction is COMPILE-FREE (DESIGN.md §10): engines take the snapshot
#    state as runtime args over warmed M-buckets, so folding mutations into
#    a fresh snapshot re-dispatched every existing trace.
print(f"compactions: {ms['n_compactions']}, engine compiles per "
      f"compaction: {ms['engine_compiles_per_compaction']:.0f}, "
      f"mean build {1e3 * ms['compaction_s_total'] / ms['n_compactions']:.0f}ms")
assert ms["engine_compiles_per_compaction"] == 0, ms
print("every mid-stream query matched a fresh full rebuild exactly.")
